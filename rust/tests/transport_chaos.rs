//! Elastic-pool chaos/soak harness — daemons die, drain, and join under
//! live multi-tenant traffic, and the determinism contract must not
//! notice.
//!
//! Load-bearing invariants:
//!
//! 1. A daemon killed mid-run under `failover = "migrate"` costs
//!    nothing numerically: shadow checkpoints restore its shards onto a
//!    promoted standby, the lost interval's fits re-dispatch, and the
//!    final train AND eval curves are **bit-identical** to an
//!    uninterrupted baseline. Every transiently lost fit names its
//!    (user, site).
//! 2. Proactive heartbeats (`heartbeat_interval >= 1`) catch a death at
//!    the next interval boundary BEFORE dispatch — same bit-identical
//!    curves, zero lost fits.
//! 3. Graceful elasticity: `Trainer::drain_worker` / `add_worker`
//!    resize the pool mid-run with live bit-exact state migration —
//!    curves unchanged, the drained daemon left empty.
//! 4. Concurrent tenants survive chaos independently: one tenant's
//!    daemon kill never moves the other tenant's curves either.
//! 5. `WorkerPool::connect_tcp` substitutes a standby for an
//!    unreachable primary instead of aborting the pool (regression).
//! 6. Offline resize (`cola pool --add` / `rebalance_daemons`) migrates
//!    existing daemon state instead of erroring — the replacement for
//!    the old `verify_shard_count` hard reject.
//! 7. Buddy replication (`replicate = true`) makes a kill free: the
//!    dead member's shards are promoted from their buddy replicas in
//!    place — zero lost fits, zero stall intervals, zero migration
//!    bytes — and the curves still match the uninterrupted baseline.
//! 8. The worker registry (`registry_listen` / `cola worker --join`)
//!    bootstraps all-dynamic fleets and admits mid-run joiners at sweep
//!    boundaries without moving any curve.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use cola::adapters::{AdapterParams, OptimizerCfg, SiteAdapter};
use cola::config::{AdapterKind, FailoverPolicy, Method, Mode, Optimizer, Task,
                   TrainConfig, TransportKind};
use cola::coordinator::{member_keys, rebalance_daemons, rendezvous_owner, FitJob,
                        RunReport, Trainer, WorkerPool};
use cola::rng::Rng;
use cola::runtime::Manifest;
use cola::tensor::Tensor;
use cola::transport::tcp::{request_daemon_shutdown, TcpLinkOpts, TcpWorker,
                           WorkerDaemon};
use cola::transport::Transport;

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(std::path::Path::new("artifacts")).unwrap())
}

/// Daemon on an ephemeral loopback port; returns (daemon, addr).
fn daemon() -> (WorkerDaemon, String) {
    let d = WorkerDaemon::bind("127.0.0.1:0", cola::config::OffloadTarget::NativeCpu,
                               manifest(), None)
        .unwrap();
    let addr = d.local_addr().to_string();
    (d, addr)
}

/// Multi-user merged-mode CLM: the hardest determinism shape (merged
/// delta adds are order-sensitive float sums) with enough users that
/// every pool member owns someone.
fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.task = Task::Clm;
    cfg.size = "tiny".into();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.mode = Mode::Merged;
    cfg.optimizer = Optimizer::Sgd;
    cfg.users = 4;
    cfg.batch = 8;
    cfg.steps = 12;
    cfg.interval = 2;
    cfg.eval_every = 4;
    cfg.eval_batches = 2;
    cfg.lr = 0.05;
    cfg.seed = seed;
    cfg.workers = 2;
    cfg
}

fn chaos_cfg(addrs: &[&str], standbys: &[&str], seed: u64, tenant: &str) -> TrainConfig {
    let mut cfg = base_cfg(seed);
    cfg.offload_transport = TransportKind::Tcp;
    cfg.worker_addrs = addrs.iter().map(|s| s.to_string()).collect();
    cfg.standby_addrs = standbys.iter().map(|s| s.to_string()).collect();
    cfg.failover = FailoverPolicy::Migrate;
    cfg.offload_tenant = tenant.to_string();
    cfg.offload_batch = true;
    cfg.offload_inflight = 2;
    cfg
}

fn run(cfg: TrainConfig) -> RunReport {
    Trainer::new(cfg).unwrap().run().unwrap()
}

fn assert_curves_eq(a: &RunReport, b: &RunReport, what: &str) {
    // f64 == compares bit patterns here: both runs must be EXACTLY equal
    assert_eq!(a.train_loss.points, b.train_loss.points,
               "{what}: train curves diverged");
    assert_eq!(a.eval_loss.points, b.eval_loss.points,
               "{what}: eval curves diverged");
}

/// Which of two daemons owns user 0 under the live rendezvous mapping —
/// the one worth killing if the test wants guaranteed lost fits.
fn victim_of(addr_a: &str, addr_b: &str) -> bool {
    let keys = member_keys(&[addr_a.to_string(), addr_b.to_string()]);
    keys[rendezvous_owner(&keys, 0).unwrap()] == addr_a
}

/// Invariant 1 (the acceptance criterion): the ENTIRE primary fleet is
/// killed between interval boundaries with reactive detection
/// (`heartbeat_interval = 0`). The next flush loses every in-flight fit
/// — each one named — both standbys are promoted, every shard restores
/// from its shadow checkpoint, the lost fits re-dispatch, and the
/// recovered run's train + eval curves are bit-identical to the
/// uninterrupted baseline.
#[test]
fn reactive_kill_names_lost_fits_and_keeps_curves_bit_identical() {
    let r_base = run(base_cfg(42));

    let (mut d_a, addr_a) = daemon();
    let (mut d_b, addr_b) = daemon();
    let (d_c, addr_c) = daemon();
    let (d_d, addr_d) = daemon();

    let mut cfg = chaos_cfg(&[&addr_a, &addr_b], &[&addr_c, &addr_d], 42, "chaos");
    cfg.heartbeat_interval = 0; // reactive: the lost fits ARE the detector
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr
        .run_with_hook(|_, t| {
            // the kill lands between steps; the t=5 flush dispatches
            // into the dead sockets and must recover everything
            if t == 4 {
                d_a.kill();
                d_b.kill();
            }
            Ok(())
        })
        .unwrap();

    assert_curves_eq(&r_base, &report, "reactive fleet kill + migrate");
    let lost = tr.lost_fits();
    assert!(!lost.is_empty(), "a fleet kill before a dispatching flush must lose fits");
    for (user, site) in lost {
        assert!(*user < 4, "lost fit names an unknown user {user}");
        assert!(!site.is_empty(), "lost fit must name its site");
    }
    assert_eq!(report.timings.lost_fits as usize, lost.len());
    assert!(report.timings.migrations >= 1);
    assert!(report.timings.migrated_state_bytes > 0);
    assert!(report.timings.stall_intervals >= 1);
    drop(tr);

    for (d, addr) in [(d_c, addr_c), (d_d, addr_d)] {
        request_daemon_shutdown(&addr).unwrap();
        d.join();
    }
}

/// Invariant 2: with proactive heartbeats every flush, a death between
/// boundaries is caught BEFORE dispatch — the shards migrate from their
/// shadow checkpoints, no fit is ever lost, and curves still match the
/// baseline bit-for-bit.
#[test]
fn proactive_heartbeat_migrates_before_dispatch_with_zero_lost_fits() {
    let r_base = run(base_cfg(7));

    let (d_a, addr_a) = daemon();
    let (d_b, addr_b) = daemon();
    let (d_c, addr_c) = daemon();
    let (mut victim, survivor, survivor_addr) = if victim_of(&addr_a, &addr_b) {
        (d_a, d_b, addr_b.clone())
    } else {
        (d_b, d_a, addr_a.clone())
    };

    let mut cfg = chaos_cfg(&[&addr_a, &addr_b], &[&addr_c], 7, "proactive");
    cfg.heartbeat_interval = 1;
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr
        .run_with_hook(|_, t| {
            if t == 4 {
                victim.kill();
            }
            Ok(())
        })
        .unwrap();

    assert_curves_eq(&r_base, &report, "proactive heartbeat + migrate");
    assert!(tr.lost_fits().is_empty(),
            "heartbeat-first detection must lose nothing: {:?}", tr.lost_fits());
    assert_eq!(report.timings.lost_fits, 0);
    assert!(report.timings.migrations >= 1);
    assert!(report.timings.migrated_state_bytes > 0);
    drop(tr);

    request_daemon_shutdown(&survivor_addr).unwrap();
    survivor.join();
    request_daemon_shutdown(&addr_c).unwrap();
    d_c.join();
}

/// Invariant 3: mid-run `--drain` + `--add` (graceful elasticity). The
/// drained daemon hands every shard off bit-exactly and ends empty; the
/// added daemon takes over the users it wins; curves never move. Works
/// under `failover = "fail"` — graceful resizes need no checkpoints.
#[test]
fn drain_and_add_mid_run_keep_curves_bit_identical() {
    let r_base = run(base_cfg(11));

    let (d_a, addr_a) = daemon();
    let (d_b, addr_b) = daemon();
    let (d_c, addr_c) = daemon();
    // drain the member that owns user 0, so the drain provably moves
    // at least one user's state
    let drained = if victim_of(&addr_a, &addr_b) { addr_a.clone() } else { addr_b.clone() };
    let mut cfg = chaos_cfg(&[&addr_a, &addr_b], &[], 11, "elastic");
    cfg.failover = FailoverPolicy::Fail; // graceful ops only
    let mut tr = Trainer::new(cfg).unwrap();
    let (da, dc) = (drained.clone(), addr_c.clone());
    let report = tr
        .run_with_hook(move |trainer, t| {
            if t == 4 {
                trainer.drain_worker(&da)?;
            }
            if t == 8 {
                trainer.add_worker(&dc)?;
            }
            Ok(())
        })
        .unwrap();

    assert_curves_eq(&r_base, &report, "drain + add mid-run");
    assert!(tr.lost_fits().is_empty());
    assert_eq!(report.timings.migrations, 2);
    assert!(report.timings.migrated_state_bytes > 0);
    drop(tr);

    // the drained daemon is still up — and empty for this tenant (and
    // every other: nothing else registered on it)
    let probe = TcpWorker::connect(9, &drained).unwrap();
    assert_eq!(probe.state_bytes().unwrap(), 0,
               "drain must evict what it exports");
    probe.shutdown();

    for (d, addr) in [(d_a, addr_a), (d_b, addr_b), (d_c, addr_c)] {
        request_daemon_shutdown(&addr).unwrap();
        d.join();
    }
}

/// Invariant 4 (the multi-tenant soak): two trainers under distinct
/// tenants share the same two daemons while one daemon is killed
/// mid-run. BOTH tenants' supervisors fail over independently (each
/// promotes the shared standby under its own tenant namespace), and
/// BOTH final curve sets are bit-identical to their baselines —
/// membership churn is invisible to every tenant, no matter where in
/// its interval the death lands.
#[test]
fn concurrent_tenants_survive_a_shared_daemon_kill() {
    let r_base_1 = run(base_cfg(42));
    let r_base_2 = run(base_cfg(43));

    let (d_a, addr_a) = daemon();
    let (d_b, addr_b) = daemon();
    let (d_c, addr_c) = daemon();
    let (mut victim, survivor, survivor_addr) = if victim_of(&addr_a, &addr_b) {
        (d_a, d_b, addr_b.clone())
    } else {
        (d_b, d_a, addr_a.clone())
    };

    let cfg1 = chaos_cfg(&[&addr_a, &addr_b], &[&addr_c], 42, "tenant-1");
    let cfg2 = chaos_cfg(&[&addr_a, &addr_b], &[&addr_c], 43, "tenant-2");
    // construct (and register) both trainers BEFORE any chaos, so the
    // kill can only ever land mid-training, never mid-registration
    let mut tr1 = Trainer::new(cfg1).unwrap();
    let mut tr2 = Trainer::new(cfg2).unwrap();
    let (r1, r2) = std::thread::scope(|s| {
        let h1 = s.spawn(move || {
            tr1.run_with_hook(|_, t| {
                if t == 4 {
                    victim.kill();
                }
                Ok(())
            })
            .unwrap()
        });
        let h2 = s.spawn(move || tr2.run().unwrap());
        (h1.join().unwrap(), h2.join().unwrap())
    });

    assert_curves_eq(&r_base_1, &r1, "tenant 1 under its own chaos");
    assert_curves_eq(&r_base_2, &r2, "tenant 2 under a neighbor's chaos");

    request_daemon_shutdown(&survivor_addr).unwrap();
    survivor.join();
    request_daemon_shutdown(&addr_c).unwrap();
    d_c.join();
}

/// Invariant 5 (regression for the connect-time bug): a dead primary
/// address used to abort the whole pool; with standbys it must be
/// substituted, and without them the error must say so.
#[test]
fn connect_tcp_substitutes_standby_for_dead_primary() {
    let (d_live, addr_live) = daemon();
    let (d_sb, addr_sb) = daemon();
    // a port that was just free: bind, read it back, release it
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let link = TcpLinkOpts {
        attempts: 2,
        base: Duration::from_millis(5),
        ..TcpLinkOpts::default()
    };

    // without standbys the pool still aborts — but says why
    let err = WorkerPool::connect_tcp(
        &[dead_addr.clone(), addr_live.clone()],
        &link,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("no standby"), "{err:#}");

    // with a standby the slot is substituted and the pool serves
    let (pool, rest) = WorkerPool::connect_tcp_with_standbys(
        &[dead_addr, addr_live.clone()],
        &[addr_sb.clone()],
        &link,
    )
    .unwrap();
    assert_eq!(pool.len(), 2);
    assert!(rest.is_empty(), "the standby was consumed by the substitution");
    for m in pool.members() {
        m.transport().ping().unwrap();
    }
    drop(pool);

    for (d, addr) in [(d_live, addr_live), (d_sb, addr_sb)] {
        request_daemon_shutdown(&addr).unwrap();
        d.join();
    }
}

/// Invariant 7 (the ISSUE's acceptance chaos test): with buddy
/// replication on, killing a registered member costs literally nothing.
/// Every post-interval refresh pushed each shard's state blob to its
/// rendezvous runner-up; the heartbeat sweep catches the death before
/// dispatch, and the survivor remap re-homes each dead shard onto
/// exactly that runner-up — so `fail_over` promotes the local replica
/// in place instead of shipping a checkpoint: zero lost fits, zero
/// stall intervals, zero migration bytes, bit-identical curves.
#[test]
fn buddy_replication_absorbs_a_kill_with_zero_recovery_cost() {
    let r_base = run(base_cfg(21));

    let (d_a, addr_a) = daemon();
    let (d_b, addr_b) = daemon();
    let (mut victim, survivor, survivor_addr) = if victim_of(&addr_a, &addr_b) {
        (d_a, d_b, addr_b.clone())
    } else {
        (d_b, d_a, addr_a.clone())
    };

    let mut cfg = chaos_cfg(&[&addr_a, &addr_b], &[], 21, "buddy");
    cfg.heartbeat_interval = 1; // catch the death before dispatch
    cfg.replicate = true;
    let mut tr = Trainer::new(cfg).unwrap();
    let report = tr
        .run_with_hook(|_, t| {
            if t == 4 {
                victim.kill();
            }
            Ok(())
        })
        .unwrap();

    assert_curves_eq(&r_base, &report, "buddy promotion after kill");
    assert_eq!(report.timings.lost_fits, 0, "promotion must lose no fits");
    assert_eq!(report.timings.stall_intervals, 0,
               "promotion must need no recovery rounds");
    assert!(report.timings.shard_promotions > 0,
            "the kill was absorbed by checkpoints, not buddy promotion");
    assert_eq!(report.timings.migrated_state_bytes, 0,
               "in-place promotion must move zero blob bytes");
    assert!(report.timings.migrations >= 1);
    drop(tr);

    request_daemon_shutdown(&survivor_addr).unwrap();
    survivor.join();
}

/// Invariant 8a: a fleet with NO static `worker_addrs` bootstraps
/// entirely from `cola worker --join` announcements, and the joined
/// member ends the run Active (and not static) in the registry. The
/// curves still match the in-process baseline — membership provenance
/// is invisible to the math.
#[test]
fn all_dynamic_fleet_bootstraps_from_a_joiner() {
    let r_base = run(base_cfg(55));

    let (d_a, addr_a) = daemon();
    // reserve a port for the announce listener before the trainer
    // exists, so the joiner knows where to announce
    let reg_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let announcer = {
        let (ra, wa) = (reg_addr.clone(), addr_a.clone());
        // the trainer binds the listener during construction below;
        // retry around the window where the reserved port is not yet
        // re-bound
        std::thread::spawn(move || -> anyhow::Result<()> {
            let mut last = None;
            for _ in 0..5 {
                match cola::coordinator::join_coordinator(&ra, &wa) {
                    Ok(()) => return Ok(()),
                    Err(e) => last = Some(e),
                }
                std::thread::sleep(Duration::from_millis(250));
            }
            Err(last.unwrap())
        })
    };

    let mut cfg = chaos_cfg(&[], &[], 55, "dynamic");
    cfg.heartbeat_interval = 1;
    cfg.registry_listen = reg_addr;
    let mut tr = Trainer::new(cfg).unwrap();
    announcer.join().unwrap().unwrap();
    let report = tr.run().unwrap();

    assert_curves_eq(&r_base, &report, "fleet bootstrapped from --join");
    {
        use cola::coordinator::MemberState;
        let reg = tr.registry().expect("tcp trainer must hold a registry");
        let g = reg.lock().unwrap();
        assert_eq!(g.state(&addr_a), Some(MemberState::Active));
        assert!(!g.is_static(&addr_a), "a joiner is not a static member");
    }
    drop(tr);

    request_daemon_shutdown(&addr_a).unwrap();
    d_a.join();
}

/// Invariant 8b: a daemon that announces itself MID-RUN is admitted at
/// the next sweep boundary — it walks joining -> active, static members
/// interop beside it, and no curve moves when the pool grows under it.
#[test]
fn mid_run_joiner_is_admitted_without_moving_curves() {
    let r_base = run(base_cfg(33));

    let (d_a, addr_a) = daemon(); // static bootstrap member
    let (d_b, addr_b) = daemon(); // joins mid-run

    let mut cfg = chaos_cfg(&[&addr_a], &[], 33, "joiner");
    cfg.heartbeat_interval = 1;
    cfg.registry_listen = "127.0.0.1:0".into();
    let mut tr = Trainer::new(cfg).unwrap();
    let reg_addr = tr.registry_addr().expect("registry listener must be bound").to_string();

    let mut joined = false;
    let join_target = addr_b.clone();
    let report = tr
        .run_with_hook(move |_, t| {
            if t == 4 && !joined {
                cola::coordinator::join_coordinator(&reg_addr, &join_target)?;
                joined = true;
            }
            Ok(())
        })
        .unwrap();

    assert_curves_eq(&r_base, &report, "mid-run join");
    {
        use cola::coordinator::MemberState;
        let g = tr.registry().unwrap().lock().unwrap();
        assert_eq!(g.state(&addr_b), Some(MemberState::Active),
                   "mid-run joiner never reached active");
        // the bootstrap member really is the static one
        assert_eq!(g.state(&addr_a), Some(MemberState::Active));
        assert!(g.is_static(&addr_a));
        assert!(!g.is_static(&addr_b));
    }
    drop(tr);

    for (d, addr) in [(d_a, addr_a), (d_b, addr_b)] {
        request_daemon_shutdown(&addr).unwrap();
        d.join();
    }
}

fn lowrank_adapter(seed: u64) -> SiteAdapter {
    let mut rng = Rng::new(seed);
    let params = AdapterParams::init(AdapterKind::LowRank, 8, 8, 4, 4, &mut rng);
    SiteAdapter::new("s", params, &OptimizerCfg::adamw(1e-3, 1e-4))
}

fn job(user: usize) -> FitJob {
    FitJob {
        user,
        site: "s".into(),
        x: Tensor::from_fn(&[3, 8], |i| (i as f32).sin()),
        ghat: Tensor::from_fn(&[3, 8], |i| (i as f32).cos()),
        grad_scale: 1.0,
        merged: false,
    }
}

/// Invariant 6 (the other acceptance criterion): growing a pool with
/// live state no longer errors — `rebalance_daemons` (the engine behind
/// `cola pool --add`) moves exactly the re-homed users' shards,
/// bit-exactly (optimizer moments included: the post-move fit equals
/// the never-moved fit), and evicts the source copies.
#[test]
fn offline_pool_add_migrates_existing_state_instead_of_erroring() {
    const USERS: usize = 32;
    let (d_a, addr_a) = daemon();
    let (d_b, addr_b) = daemon();
    let (d_c, addr_c) = daemon();
    let link = TcpLinkOpts { tenant: "resize".into(), ..TcpLinkOpts::default() };

    let two = vec![addr_a.clone(), addr_b.clone()];
    let three = vec![addr_a.clone(), addr_b.clone(), addr_c.clone()];
    let keys2 = member_keys(&two);
    let keys3 = member_keys(&three);

    // a finished run's worth of live state: adapters with stepped AdamW
    // moments, placed by the same rendezvous mapping the trainer uses
    let conn = |addr: &str| TcpWorker::connect_with_link_opts(0, addr, &link).unwrap();
    let (wa, wb, wc) = (conn(&addr_a), conn(&addr_b), conn(&addr_c));
    let by_addr: std::collections::BTreeMap<&str, &TcpWorker> = [
        (addr_a.as_str(), &wa),
        (addr_b.as_str(), &wb),
        (addr_c.as_str(), &wc),
    ]
    .into_iter()
    .collect();
    for user in 0..USERS {
        let owner = &keys2[rendezvous_owner(&keys2, user).unwrap()];
        let w = by_addr[owner.as_str()];
        w.register(user, "s", lowrank_adapter(100 + user as u64)).unwrap();
        w.fit(job(user)).unwrap().recv().unwrap().unwrap();
    }
    // reference: what each user's NEXT fit returns if nothing ever moves
    let reference: Vec<Vec<Tensor>> = (0..USERS)
        .map(|user| {
            let shadow = cola::coordinator::WorkerCore::new(
                0, cola::config::OffloadTarget::NativeCpu, manifest(), None);
            let owner = &keys2[rendezvous_owner(&keys2, user).unwrap()];
            let blob = by_addr[owner.as_str()].export_state(user, "s").unwrap();
            shadow.import_state("", &blob).unwrap();
            shadow.fit("", job(user)).unwrap().new_params.unwrap()
        })
        .collect();

    let stats = rebalance_daemons(&two, &three, USERS, &["s".into()], &link).unwrap();
    assert!(stats.users_moved > 0, "32 users and nobody moved to the new daemon");
    assert_eq!(stats.shards_moved, stats.users_moved); // one site each
    assert!(stats.bytes_moved > 0);

    for user in 0..USERS {
        let old_owner = &keys2[rendezvous_owner(&keys2, user).unwrap()];
        let new_owner = &keys3[rendezvous_owner(&keys3, user).unwrap()];
        let w_new = by_addr[cola::coordinator::key_addr(new_owner)];
        // the (possibly migrated) state serves a fit bit-identical to
        // the never-migrated reference — moments made the trip intact
        let r = w_new.fit(job(user)).unwrap().recv().unwrap().unwrap();
        for (x, y) in r.new_params.unwrap().iter().zip(&reference[user]) {
            assert_eq!(x, y, "user {user}: post-migration fit diverged");
        }
        if old_owner != new_owner {
            assert_eq!(new_owner, &keys3[2], "adds may only move users TO the new member");
            // and the source copy was evicted
            let err = by_addr[cola::coordinator::key_addr(old_owner)]
                .snapshot(user, "s")
                .unwrap_err();
            assert!(format!("{err:#}").contains("no adapter"), "{err:#}");
        }
    }

    drop(by_addr); // release the borrows before moving the workers
    for w in [wa, wb, wc] {
        w.shutdown();
    }
    for (d, addr) in [(d_a, addr_a), (d_b, addr_b), (d_c, addr_c)] {
        request_daemon_shutdown(&addr).unwrap();
        d.join();
    }
}
