//! Golden-value parity tests for the native backend: pin
//! `runtime::native` outputs for each artifact family against small
//! fixtures derived from `python/compile/kernels/ref.py`, plus
//! manifest.json parse round-trips for the built-in manifest.

use std::collections::BTreeMap;
use std::path::Path;

use cola::runtime::{Input, Manifest, OutputPlan, Runtime, Value};
use cola::tensor::{self, Tensor};

fn runtime() -> Runtime {
    // no artifacts directory in a clean checkout -> built-in native manifest
    Runtime::load("artifacts").expect("native runtime")
}

fn exec(
    rt: &Runtime,
    artifact: &str,
    by_name: &BTreeMap<String, Value>,
    fetch: &[&str],
) -> BTreeMap<String, Value> {
    let inputs = rt
        .assemble(artifact, |io| {
            by_name
                .get(&io.name)
                .cloned()
                .map(Input::Val)
                .ok_or_else(|| anyhow::anyhow!("missing {}", io.name))
        })
        .unwrap();
    let (outs, _) = rt.execute_fetch(&rt.server, artifact, inputs, fetch).unwrap();
    outs
}

#[test]
fn manifest_roundtrip_through_json() {
    let rt = runtime();
    assert!(!rt.manifest.from_disk);
    let json = rt.manifest.to_json_string();
    let parsed = Manifest::parse(&json, Path::new("artifacts")).unwrap();
    assert_eq!(parsed.artifacts.len(), rt.manifest.artifacts.len());
    assert_eq!(parsed.rank, rt.manifest.rank);
    assert_eq!(parsed.mlp_hidden, rt.manifest.mlp_hidden);
    assert_eq!(parsed.n_classes_seqcls, rt.manifest.n_classes_seqcls);
    for (name, spec) in &rt.manifest.artifacts {
        let p = parsed.artifact(name).unwrap();
        assert_eq!(p.outputs, spec.outputs, "{name}");
        assert_eq!(p.inputs.len(), spec.inputs.len(), "{name}");
        for (a, b) in p.inputs.iter().zip(&spec.inputs) {
            assert_eq!(a.name, b.name, "{name}");
            assert_eq!(a.dtype, b.dtype, "{name}");
            assert_eq!(a.dims, b.dims, "{name}");
        }
    }
    for (name, c) in &rt.manifest.configs {
        let p = &parsed.configs[name];
        assert_eq!((p.vocab, p.d, p.layers), (c.vocab, c.d, c.layers));
        assert_eq!((p.heads, p.dff, p.seq, p.batch), (c.heads, c.dff, c.seq, c.batch));
    }
}

#[test]
fn fit_linear_golden_values() {
    // ref.py fit_step_linear with target = delta - ghat reduces to
    // dW = x^T ghat; pin against a one-hot fixture.
    let rt = runtime();
    let mut x = Tensor::zeros(&[8, 128]);
    x.data_mut()[0] = 1.0; // x[0][0] = 1
    x.data_mut()[128 + 2] = 2.0; // x[1][2] = 2
    let mut ghat = Tensor::zeros(&[8, 4]);
    ghat.data_mut()[0..4].copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
    ghat.data_mut()[4] = 5.0; // ghat[1][0] = 5
    let mut ins = BTreeMap::new();
    ins.insert("x".to_string(), Value::F32(x));
    ins.insert("ghat".to_string(), Value::F32(ghat));
    ins.insert("W".to_string(), Value::F32(Tensor::zeros(&[128, 4])));
    let outs = exec(&rt, "fit_linear_128x4_n8", &ins, &["dW"]);
    let dw = outs["dW"].as_f32().unwrap();
    assert_eq!(dw.shape(), &[128, 4]);
    // row 0 of dW = x[.,0]^T ghat = [1,2,3,4]
    assert_eq!(&dw.data()[0..4], &[1.0, 2.0, 3.0, 4.0]);
    // row 2 of dW = 2 * ghat[1] = [10,0,0,0]
    assert_eq!(&dw.data()[2 * 4..2 * 4 + 4], &[10.0, 0.0, 0.0, 0.0]);
    // everything else zero
    assert_eq!(dw.data()[3 * 4], 0.0);
}

#[test]
fn fit_lowrank_matches_native_contractions() {
    let rt = runtime();
    let mut rng = cola::rng::Rng::new(9);
    let x = Tensor::randn(&[512, 128], 1.0, &mut rng);
    let ghat = Tensor::randn(&[512, 128], 1.0, &mut rng);
    let a = Tensor::randn(&[128, 8], 0.2, &mut rng);
    let b = Tensor::randn(&[8, 128], 0.2, &mut rng);
    let mut ins = BTreeMap::new();
    ins.insert("x".to_string(), Value::F32(x.clone()));
    ins.insert("ghat".to_string(), Value::F32(ghat.clone()));
    ins.insert("A".to_string(), Value::F32(a.clone()));
    ins.insert("B".to_string(), Value::F32(b.clone()));
    let outs = exec(&rt, "fit_lowrank_128x128_n512", &ins, &["dA", "dB"]);
    // ref.py: da = x^T (ghat B^T); db = (xA)^T ghat
    let da_ref = tensor::matmul_tn(&x, &tensor::matmul_nt(&ghat, &b));
    let db_ref = tensor::matmul_tn(&tensor::matmul(&x, &a), &ghat);
    assert!(outs["dA"].as_f32().unwrap().allclose(&da_ref, 1e-4, 1e-4));
    assert!(outs["dB"].as_f32().unwrap().allclose(&db_ref, 1e-4, 1e-4));
}

#[test]
fn adamw_golden_step() {
    // With eps = 0, wd = 0, t = 1: mhat = g, vhat = g^2 -> w2 = -lr*sign(g).
    let rt = runtime();
    let mut ins = BTreeMap::new();
    ins.insert("w".to_string(), Value::F32(Tensor::zeros(&[64])));
    ins.insert("g".to_string(), Value::F32(Tensor::from_fn(&[64], |_| 1.0)));
    ins.insert("m".to_string(), Value::F32(Tensor::zeros(&[64])));
    ins.insert("v".to_string(), Value::F32(Tensor::zeros(&[64])));
    ins.insert("t".to_string(), Value::F32(Tensor::scalar(1.0)));
    ins.insert("lr".to_string(), Value::F32(Tensor::scalar(0.1)));
    ins.insert("beta1".to_string(), Value::F32(Tensor::scalar(0.9)));
    ins.insert("beta2".to_string(), Value::F32(Tensor::scalar(0.999)));
    ins.insert("eps".to_string(), Value::F32(Tensor::scalar(0.0)));
    ins.insert("wd".to_string(), Value::F32(Tensor::scalar(0.0)));
    let outs = exec(&rt, "adamw_n64", &ins, &["w2", "m2", "v2"]);
    let w2 = outs["w2"].as_f32().unwrap();
    for &v in w2.data() {
        assert!((v + 0.1).abs() < 1e-5, "w2 {v}");
    }
    let m2 = outs["m2"].as_f32().unwrap();
    assert!((m2.data()[0] - 0.1).abs() < 1e-6);
}

#[test]
fn sgd_golden_step() {
    let rt = runtime();
    let mut ins = BTreeMap::new();
    ins.insert("w".to_string(), Value::F32(Tensor::from_fn(&[64], |_| 1.0)));
    ins.insert("g".to_string(), Value::F32(Tensor::from_fn(&[64], |_| 0.5)));
    ins.insert("lr".to_string(), Value::F32(Tensor::scalar(0.1)));
    ins.insert("wd".to_string(), Value::F32(Tensor::scalar(0.01)));
    let outs = exec(&rt, "sgd_n64", &ins, &["w2"]);
    // w - lr*(g + wd*w) = 1 - 0.1*(0.5 + 0.01) = 0.949
    for &v in outs["w2"].as_f32().unwrap().data() {
        assert!((v - 0.949).abs() < 1e-6);
    }
}

fn lm_zero_inputs(rt: &Runtime) -> BTreeMap<String, Value> {
    let spec = rt.manifest.artifact("lm_fwdbwd_tiny_none").unwrap();
    let mut ins = BTreeMap::new();
    for io in &spec.inputs {
        let v = match io.name.as_str() {
            "tokens" => Value::I32(cola::runtime::IntTensor::new(
                vec![8, 64],
                vec![7; 8 * 64],
            )),
            "targets" => Value::I32(cola::runtime::IntTensor::new(
                vec![8, 64],
                vec![0; 8 * 64],
            )),
            "mask" => Value::F32(Tensor::from_fn(&[8, 64], |_| 1.0)),
            _ => Value::F32(Tensor::zeros(&io.dims)),
        };
        ins.insert(io.name.clone(), v);
    }
    ins
}

#[test]
fn lm_fwdbwd_uniform_logits_golden() {
    // All-zero weights => logits identically zero => loss = ln(V) exactly,
    // argmax = 0 everywhere => acc = 1 with targets = 0, and every
    // grad_hhat must vanish (nothing reaches the loss through zeros).
    let rt = runtime();
    let ins = lm_zero_inputs(&rt);
    let outs = exec(
        &rt,
        "lm_fwdbwd_tiny_none",
        &ins,
        &["loss", "acc", "l0.x", "l0.gq", "l1.gv"],
    );
    let loss = outs["loss"].scalar_f32().unwrap();
    assert!((loss - (512f32).ln()).abs() < 1e-4, "loss {loss}");
    assert!((outs["acc"].scalar_f32().unwrap() - 1.0).abs() < 1e-6);
    assert_eq!(outs["l0.x"].shape(), &[8, 64, 128]);
    assert_eq!(tensor::norm(outs["l0.gq"].as_f32().unwrap()), 0.0);
    assert_eq!(tensor::norm(outs["l1.gv"].as_f32().unwrap()), 0.0);
}

#[test]
fn decoupled_lowrank_equals_merged_forward() {
    // Prop. 2 at artifact level: running the lowrank graph with live
    // adapters equals the 'none' graph with the deltas folded into wq/wv.
    let rt = runtime();
    let mut rng = cola::rng::Rng::new(3);
    let weights = rt.manifest.load_init("lm_tiny").unwrap();
    let mut adapters = rt.manifest.load_init("adapters_tiny_lowrank").unwrap();
    // randomize B so the delta is non-trivial
    for (name, t) in adapters.iter_mut() {
        if name.ends_with(".B") {
            *t = Tensor::randn(&t.shape().to_vec(), 0.2, &mut rng);
        }
    }
    let tokens = Value::I32(cola::runtime::IntTensor::new(
        vec![8, 64],
        (0..8 * 64).map(|i| (i % 500) as i32).collect(),
    ));
    let targets = Value::I32(cola::runtime::IntTensor::new(
        vec![8, 64],
        (0..8 * 64).map(|i| ((i + 1) % 500) as i32).collect(),
    ));
    let mask = Value::F32(Tensor::from_fn(&[8, 64], |_| 1.0));

    let mut ins = BTreeMap::new();
    for (k, v) in &weights {
        ins.insert(k.clone(), Value::F32(v.clone()));
    }
    for (k, v) in &adapters {
        ins.insert(k.clone(), Value::F32(v.clone()));
    }
    ins.insert("tokens".to_string(), tokens.clone());
    ins.insert("targets".to_string(), targets.clone());
    ins.insert("mask".to_string(), mask.clone());
    let live = exec(&rt, "lm_fwdbwd_tiny_lowrank", &ins, &["loss", "l0.gq"]);

    // fold deltas into the q/v projections
    let mut merged = weights.clone();
    for i in 0..2 {
        for proj in ["q", "v"] {
            let a = &adapters[&format!("l{i}.{proj}.A")];
            let b = &adapters[&format!("l{i}.{proj}.B")];
            let delta = tensor::matmul(a, b);
            let w = merged.get_mut(&format!("l{i}.w{proj}")).unwrap();
            tensor::axpy(w, 1.0, &delta);
        }
    }
    let mut ins2 = BTreeMap::new();
    for (k, v) in &merged {
        ins2.insert(k.clone(), Value::F32(v.clone()));
    }
    ins2.insert("tokens".to_string(), tokens);
    ins2.insert("targets".to_string(), targets);
    ins2.insert("mask".to_string(), mask);
    let folded = exec(&rt, "lm_fwdbwd_tiny_none", &ins2, &["loss", "l0.gq"]);

    let l1 = live["loss"].scalar_f32().unwrap();
    let l2 = folded["loss"].scalar_f32().unwrap();
    assert!((l1 - l2).abs() < 1e-3, "live {l1} vs folded {l2}");
    let g1 = live["l0.gq"].as_f32().unwrap();
    let g2 = folded["l0.gq"].as_f32().unwrap();
    assert!(g1.allclose(g2, 1e-2, 1e-3), "max {}", g1.max_abs_diff(g2));
}

#[test]
fn coupled_lora_grads_satisfy_prop1() {
    // Prop. 1 at artifact level: the coupled LoRA gradient for site B
    // equals the surrogate-fit contraction of the decoupled outputs
    // (x_m, grad_hhat_m) shipped by the lowrank graph on the same batch.
    let rt = runtime();
    let weights = rt.manifest.load_init("lm_tiny").unwrap();
    let tunables = rt.manifest.load_init("tunables_tiny_lora").unwrap();
    let tokens = Value::I32(cola::runtime::IntTensor::new(
        vec![8, 64],
        (0..8 * 64).map(|i| (i * 31 % 500) as i32).collect(),
    ));
    let targets = Value::I32(cola::runtime::IntTensor::new(
        vec![8, 64],
        (0..8 * 64).map(|i| (i * 17 % 500) as i32).collect(),
    ));
    let mask = Value::F32(Tensor::from_fn(&[8, 64], |_| 1.0));
    let mut ins = BTreeMap::new();
    for (k, v) in weights.iter().chain(tunables.iter()) {
        ins.insert(k.clone(), Value::F32(v.clone()));
    }
    ins.insert("tokens".to_string(), tokens.clone());
    ins.insert("targets".to_string(), targets.clone());
    ins.insert("mask".to_string(), mask.clone());
    let coupled = exec(&rt, "coupled_clm_tiny_lora", &ins,
                       &["loss", "d.l0.q.A", "d.l0.q.B"]);

    // same batch through the decoupled graph (adapter inputs == tunables)
    let dec = exec(&rt, "lm_fwdbwd_tiny_lowrank", &ins, &["loss", "l0.x", "l0.gq"]);
    assert!(
        (coupled["loss"].scalar_f32().unwrap() - dec["loss"].scalar_f32().unwrap()).abs()
            < 1e-5
    );
    let x = dec["l0.x"].as_f32().unwrap().clone().to_rows();
    let gq = dec["l0.gq"].as_f32().unwrap().clone().to_rows();
    let a = &tunables["l0.q.A"];
    let b = &tunables["l0.q.B"];
    let da_fit = tensor::matmul_tn(&x, &tensor::matmul_nt(&gq, b));
    let db_fit = tensor::matmul_tn(&tensor::matmul(&x, a), &gq);
    let da = coupled["d.l0.q.A"].as_f32().unwrap();
    let db = coupled["d.l0.q.B"].as_f32().unwrap();
    assert!(da.allclose(&da_fit, 1e-3, 1e-4), "dA max {}", da.max_abs_diff(&da_fit));
    assert!(db.allclose(&db_fit, 1e-3, 1e-4), "dB max {}", db.max_abs_diff(&db_fit));
}

#[test]
fn seqcls_zero_head_golden() {
    let rt = runtime();
    let spec = rt.manifest.artifact("seqcls_fwdbwd_tiny_none").unwrap();
    let mut ins = BTreeMap::new();
    for io in &spec.inputs {
        let v = match io.name.as_str() {
            "tokens" => Value::I32(cola::runtime::IntTensor::new(
                vec![8, 64],
                vec![20; 8 * 64],
            )),
            "labels" => Value::I32(cola::runtime::IntTensor::new(vec![8], vec![0; 8])),
            "mask" => Value::F32(Tensor::from_fn(&[8, 64], |_| 1.0)),
            _ => Value::F32(Tensor::zeros(&io.dims)),
        };
        ins.insert(io.name.clone(), v);
    }
    let outs = exec(&rt, "seqcls_fwdbwd_tiny_none", &ins,
                    &["loss", "acc", "head.x", "head.g"]);
    let loss = outs["loss"].scalar_f32().unwrap();
    assert!((loss - (4f32).ln()).abs() < 1e-5, "loss {loss}");
    // head.g = (softmax - onehot)/B with uniform softmax over 4 classes
    let hg = outs["head.g"].as_f32().unwrap();
    assert_eq!(hg.shape(), &[8, 4]);
    assert!((hg.data()[0] - (0.25 - 1.0) / 8.0).abs() < 1e-6);
    assert!((hg.data()[1] - 0.25 / 8.0).abs() < 1e-6);
}

#[test]
fn ic_merged_zero_weights_golden() {
    let rt = runtime();
    let spec = rt.manifest.artifact("ic_linear_fwdbwd_merged").unwrap();
    let mut ins = BTreeMap::new();
    for io in &spec.inputs {
        let v = match io.name.as_str() {
            "images" => Value::F32(Tensor::from_fn(&[32, 28, 28, 1], |i| {
                (i % 7) as f32 * 0.1
            })),
            "labels" => Value::I32(cola::runtime::IntTensor::new(vec![32], vec![0; 32])),
            _ => Value::F32(Tensor::zeros(&io.dims)),
        };
        ins.insert(io.name.clone(), v);
    }
    let outs = exec(&rt, "ic_linear_fwdbwd_merged", &ins,
                    &["loss", "acc", "fc.x", "fc.g"]);
    let loss = outs["loss"].scalar_f32().unwrap();
    assert!((loss - (10f32).ln()).abs() < 1e-5, "loss {loss}");
    assert!((outs["acc"].scalar_f32().unwrap() - 1.0).abs() < 1e-6);
    assert_eq!(outs["fc.x"].shape(), &[32, 784]);
    let g = outs["fc.g"].as_f32().unwrap();
    assert!((g.data()[0] - (0.1 - 1.0) / 32.0).abs() < 1e-6);
}

#[test]
fn native_refuses_unknown_artifact_with_clear_error() {
    let rt = runtime();
    let err = rt
        .server
        .execute("bogus_artifact", vec![], OutputPlan::default())
        .unwrap_err();
    assert!(format!("{err}").contains("bogus_artifact"));
}
