//! Property-style invariant sweeps (hand-rolled harness; proptest is
//! unavailable offline). Each property runs across many random seeds
//! with shrink-free failure reporting (the seed is in the message).

use cola::adapters::{AdapterParams, OptState, OptimizerCfg};
use cola::config::AdapterKind;
use cola::coordinator::buffer::SiteBuffer;
use cola::data::lm::LmTaskGen;
use cola::data::seqcls::ClsTaskGen;
use cola::data::Split;
use cola::merge;
use cola::rng::Rng;
use cola::tensor::{self, Tensor};

const SEEDS: u64 = 24;

fn rand_lowrank(rng: &mut Rng, d: usize, r: usize) -> AdapterParams {
    AdapterParams::LowRank {
        a: Tensor::randn(&[d, r], 0.3, rng),
        b: Tensor::randn(&[r, d], 0.3, rng),
    }
}

#[test]
fn prop_merge_unmerge_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed);
        let d = 4 + rng.below(60);
        let r = 1 + rng.below(d.min(12));
        let base = Tensor::randn(&[d, d], 1.0, &mut rng);
        let p = rand_lowrank(&mut rng, d, r);
        let mut ws = std::collections::BTreeMap::from(
            [("s.W".to_string(), base.clone())]);
        merge::merge_into(&mut ws, "s", &p).unwrap();
        merge::unmerge_from(&mut ws, "s", &p).unwrap();
        assert!(ws["s.W"].allclose(&base, 1e-4, 1e-4), "seed {seed} d {d} r {r}");
    }
}

#[test]
fn prop_merged_forward_equals_adapter_forward() {
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let d = 4 + rng.below(48);
        let n = 1 + rng.below(32);
        let base = Tensor::randn(&[d, d], 1.0, &mut rng);
        for kind in [AdapterKind::LowRank, AdapterKind::Linear] {
            let mut p = AdapterParams::init(kind, d, d, 4, 8, &mut rng);
            // randomize so the delta is non-trivial
            for t in p.tensors_mut() {
                *t = Tensor::randn(&t.shape().to_vec(), 0.2, &mut rng);
            }
            let x = Tensor::randn(&[n, d], 1.0, &mut rng);
            let live = tensor::add(&tensor::matmul(&x, &base), &p.apply(&x));
            let mut ws = std::collections::BTreeMap::from(
                [("s.W".to_string(), base.clone())]);
            merge::merge_into(&mut ws, "s", &p).unwrap();
            let merged = tensor::matmul(&x, &ws["s.W"]);
            assert!(live.allclose(&merged, 2e-3, 2e-3),
                    "seed {seed} kind {kind:?} max {}",
                    live.max_abs_diff(&merged));
        }
    }
}

#[test]
fn prop_delta_diff_telescopes() {
    // Applying delta_diff(p0,p1) then delta_diff(p1,p2) equals merging
    // p2 directly — merged-mode updates never drift.
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 0xD1FF);
        let d = 4 + rng.below(32);
        let ps: Vec<_> = (0..3).map(|_| rand_lowrank(&mut rng, d, 4)).collect();
        let base = Tensor::randn(&[d, d], 1.0, &mut rng);
        let mut w = base.clone();
        tensor::axpy(&mut w, 1.0, &ps[0].delta_matrix().unwrap());
        tensor::axpy(&mut w, 1.0, &merge::delta_diff(&ps[0], &ps[1]).unwrap());
        tensor::axpy(&mut w, 1.0, &merge::delta_diff(&ps[1], &ps[2]).unwrap());
        let mut direct = base;
        tensor::axpy(&mut direct, 1.0, &ps[2].delta_matrix().unwrap());
        assert!(w.allclose(&direct, 1e-3, 1e-3), "seed {seed}");
    }
}

#[test]
fn prop_buffer_concat_grads_equal_summed_grads() {
    // The interval invariant on the native fit path.
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 0xB0FF);
        let d = 4 + rng.below(24);
        let p = rand_lowrank(&mut rng, d, 3);
        let parts: Vec<(Tensor, Tensor)> = (0..3)
            .map(|_| {
                let n = 1 + rng.below(16);
                (Tensor::randn(&[n, d], 1.0, &mut rng),
                 Tensor::randn(&[n, d], 1.0, &mut rng))
            })
            .collect();
        let mut buf = SiteBuffer::default();
        for (x, g) in &parts {
            buf.push(x.clone(), g.clone());
        }
        let (xc, gc, scale) = buf.drain().unwrap();
        assert!((scale - 1.0 / 3.0).abs() < 1e-6);
        let cat_grads = p.fit_grads(&xc, &gc);
        let mut sum_grads = p.fit_grads(&parts[0].0, &parts[0].1);
        for (x, g) in &parts[1..] {
            for (s, gi) in sum_grads.iter_mut().zip(p.fit_grads(x, g)) {
                tensor::axpy(s, 1.0, &gi);
            }
        }
        for (c, s) in cat_grads.iter().zip(&sum_grads) {
            assert!(c.allclose(s, 1e-3, 1e-3), "seed {seed}");
        }
    }
}

#[test]
fn prop_optimizer_linear_in_lr_for_sgd() {
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 0x56D);
        let n = 1 + rng.below(64);
        let w0 = Tensor::randn(&[n], 1.0, &mut rng);
        let g = Tensor::randn(&[n], 1.0, &mut rng);
        let mut w1 = w0.clone();
        let mut o1 = OptState::new(&OptimizerCfg::sgd(0.1, 0.0), &[n]);
        o1.apply(&mut [&mut w1], std::slice::from_ref(&g));
        let mut w2 = w0.clone();
        let mut o2 = OptState::new(&OptimizerCfg::sgd(0.2, 0.0), &[n]);
        o2.apply(&mut [&mut w2], std::slice::from_ref(&g));
        // (w0 - w2) == 2 * (w0 - w1)
        let d1 = tensor::sub(&w0, &w1);
        let d2 = tensor::sub(&w0, &w2);
        assert!(tensor::scale(&d1, 2.0).allclose(&d2, 1e-5, 1e-6), "seed {seed}");
    }
}

#[test]
fn prop_data_generators_deterministic_and_split_disjoint() {
    for seed in 0..SEEDS {
        let g = LmTaskGen::new(512, 64, seed);
        let a = g.instruct_batch(4, None, Split::Train, seed);
        let b = g.instruct_batch(4, None, Split::Train, seed);
        assert_eq!(a.tokens, b.tokens, "seed {seed}");
        let e = g.instruct_batch(4, None, Split::Eval, seed);
        assert_ne!(a.tokens, e.tokens, "seed {seed}");

        let c = ClsTaskGen::new(512, 64, seed);
        let t0 = c.batch(8, (seed % 8) as usize, Split::Train, 0);
        let t1 = c.batch(8, (seed % 8) as usize, Split::Train, 0);
        assert_eq!(t0.labels, t1.labels, "seed {seed}");
    }
}

#[test]
fn prop_zero_ghat_means_zero_update() {
    // If grad_hhat is zero the surrogate gradient must vanish (the model
    // is at a stationary point for that site).
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 0x0);
        let d = 4 + rng.below(32);
        for kind in [AdapterKind::LowRank, AdapterKind::Linear, AdapterKind::Mlp] {
            let mut p = AdapterParams::init(kind, d, d, 4, 8, &mut rng);
            for t in p.tensors_mut() {
                *t = Tensor::randn(&t.shape().to_vec(), 0.3, &mut rng);
            }
            let x = Tensor::randn(&[8, d], 1.0, &mut rng);
            let z = Tensor::zeros(&[8, d]);
            for g in p.fit_grads(&x, &z) {
                assert!(tensor::norm(&g) < 1e-5, "seed {seed} kind {kind:?}");
            }
        }
    }
}

#[test]
fn prop_fit_grads_scale_linearly_in_ghat() {
    // Surrogate gradients are linear in grad_hhat for linear-in-input
    // adapters (exactness backbone of Prop. 1).
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed ^ 0x11);
        let d = 4 + rng.below(24);
        let p = rand_lowrank(&mut rng, d, 4);
        let x = Tensor::randn(&[8, d], 1.0, &mut rng);
        let g = Tensor::randn(&[8, d], 1.0, &mut rng);
        let g2 = tensor::scale(&g, 3.0);
        let gr1 = p.fit_grads(&x, &g);
        let gr2 = p.fit_grads(&x, &g2);
        for (a, b) in gr1.iter().zip(&gr2) {
            assert!(tensor::scale(a, 3.0).allclose(b, 1e-3, 1e-3), "seed {seed}");
        }
    }
}
