//! End-to-end coordinator invariants over the real artifacts:
//!
//! - Prop. 1 at system level: ColA(LowRank, unmerged) and coupled LoRA
//!   follow the same loss trajectory step for step.
//! - Merged == unmerged trajectories (Prop. 2 during training).
//! - Offload targets (native CPU vs PJRT device) are interchangeable.
//! - The merged server's resident memory is independent of K.

use cola::config::{AdapterKind, Method, Mode, OffloadTarget, Optimizer, Task,
                   TrainConfig};
use cola::coordinator::Trainer;

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.task = Task::Clm;
    cfg.size = "tiny".into();
    cfg.steps = 6;
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    cfg.optimizer = Optimizer::Sgd; // exact comparisons: no moment state
    cfg.lr = 0.05;
    cfg.seed = 42;
    cfg
}

fn run_losses(cfg: TrainConfig) -> Vec<f64> {
    let mut t = Trainer::new(cfg).unwrap();
    let r = t.run().unwrap();
    r.train_loss.points.iter().map(|(_, v)| *v).collect()
}

#[test]
fn prop1_cola_lowrank_tracks_coupled_lora() {
    let mut cola = base_cfg();
    cola.method = Method::Cola(AdapterKind::LowRank);
    cola.mode = Mode::Unmerged;
    let l_cola = run_losses(cola);

    let mut lora = base_cfg();
    lora.method = Method::Lora;
    let l_lora = run_losses(lora);

    // The adapter inits differ between the python-exported LoRA tunables
    // and the Rust-side ColA init, but both start at zero adapter output,
    // so step-0 losses are identical and the trajectories must stay close
    // (same gradient rule by Prop. 1; B starts at 0 so both first updates
    // move only B... which depends on A's init). Compare with a tolerance
    // that catches any algorithmic divergence while allowing init noise.
    assert!((l_cola[0] - l_lora[0]).abs() < 1e-4,
            "step0: {} vs {}", l_cola[0], l_lora[0]);
    for (i, (a, b)) in l_cola.iter().zip(&l_lora).enumerate() {
        assert!((a - b).abs() < 0.05, "step {i}: {a} vs {b}");
    }
    // and both must be decreasing overall
    assert!(l_cola.last().unwrap() < &l_cola[0]);
    assert!(l_lora.last().unwrap() < &l_lora[0]);
}

#[test]
fn merged_equals_unmerged_trajectory() {
    let mut unm = base_cfg();
    unm.method = Method::Cola(AdapterKind::LowRank);
    unm.mode = Mode::Unmerged;
    let l_u = run_losses(unm);

    let mut mer = base_cfg();
    mer.method = Method::Cola(AdapterKind::LowRank);
    mer.mode = Mode::Merged;
    let l_m = run_losses(mer);

    for (i, (a, b)) in l_u.iter().zip(&l_m).enumerate() {
        assert!((a - b).abs() < 2e-3, "step {i}: unmerged {a} vs merged {b}");
    }
}

#[test]
fn offload_targets_agree() {
    // native-CPU fit vs PJRT-artifact fit must produce the same
    // trajectory (they implement the same Eq. 6 update).
    let mut native = base_cfg();
    native.method = Method::Cola(AdapterKind::LowRank);
    native.offload = OffloadTarget::NativeCpu;
    let l_n = run_losses(native);

    let mut pjrt = base_cfg();
    pjrt.method = Method::Cola(AdapterKind::LowRank);
    pjrt.offload = OffloadTarget::PjrtDevice;
    let l_p = run_losses(pjrt);

    for (i, (a, b)) in l_n.iter().zip(&l_p).enumerate() {
        assert!((a - b).abs() < 2e-3, "step {i}: native {a} vs pjrt {b}");
    }
}

#[test]
fn interval_reduces_update_count_but_still_learns() {
    let mut c1 = base_cfg();
    c1.method = Method::Cola(AdapterKind::LowRank);
    c1.steps = 12;
    c1.interval = 1;
    let l1 = run_losses(c1);

    let mut c4 = base_cfg();
    c4.method = Method::Cola(AdapterKind::LowRank);
    c4.steps = 12;
    c4.interval = 4;
    let l4 = run_losses(c4);

    assert!(l1.last().unwrap() < &l1[0]);
    assert!(l4.last().unwrap() < &l4[0], "interval-4 run failed to learn");
}

#[test]
fn merged_server_memory_independent_of_users() {
    // Tables 16-18's headline: server residency does not grow with K.
    let mut one = base_cfg();
    one.method = Method::Cola(AdapterKind::LowRank);
    one.mode = Mode::Merged;
    one.users = 1;
    one.steps = 2;
    let mut t1 = Trainer::new(one).unwrap();
    let r1 = t1.run().unwrap();

    let mut four = base_cfg();
    four.method = Method::Cola(AdapterKind::LowRank);
    four.mode = Mode::Merged;
    four.users = 4;
    four.steps = 2;
    let mut t4 = Trainer::new(four).unwrap();
    let r4 = t4.run().unwrap();

    assert_eq!(r1.server_resident_bytes, r4.server_resident_bytes);
    // while worker state grows with K
    assert!(r4.worker_state_bytes > r1.worker_state_bytes);
}

#[test]
fn unmerged_server_memory_grows_with_adapter_size() {
    let mut lr = base_cfg();
    lr.method = Method::Cola(AdapterKind::LowRank);
    lr.mode = Mode::Unmerged;
    lr.steps = 1;
    let r_lr = Trainer::new(lr).unwrap().run().unwrap();

    let mut lin = base_cfg();
    lin.method = Method::Cola(AdapterKind::Linear);
    lin.mode = Mode::Unmerged;
    lin.steps = 1;
    let r_lin = Trainer::new(lin).unwrap().run().unwrap();

    assert!(r_lin.server_resident_bytes > r_lr.server_resident_bytes);
    // merged-Linear drops that back to the lowrank-merged level
    let mut lin_m = base_cfg();
    lin_m.method = Method::Cola(AdapterKind::Linear);
    lin_m.mode = Mode::Merged;
    lin_m.steps = 1;
    let r_lin_m = Trainer::new(lin_m).unwrap().run().unwrap();
    assert!(r_lin_m.server_resident_bytes < r_lin.server_resident_bytes);
}

#[test]
fn mlp_adapter_trains_unmerged_only() {
    let mut cfg = base_cfg();
    cfg.method = Method::Cola(AdapterKind::Mlp);
    cfg.mode = Mode::Merged;
    assert!(cfg.validate().is_err());

    cfg.mode = Mode::Unmerged;
    cfg.steps = 4;
    let l = run_losses(cfg);
    assert!(l.last().unwrap() <= &l[0]);
}

#[test]
fn async_offload_keeps_at_most_one_interval_in_flight() {
    // backpressure pin: with async_offload the staleness window is
    // exactly one interval of FitJobs — dispatch leaves this interval
    // outstanding, and the next flush applies it before dispatching more
    let mut cfg = base_cfg();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.async_offload = true;
    cfg.interval = 2;
    let mut t = Trainer::new(cfg).unwrap();
    let interval_jobs = t.driver.sites.len(); // users = 1
    assert!(interval_jobs > 0);
    assert_eq!(t.in_flight(), 0);
    for step in 0..6u64 {
        t.step(step).unwrap();
        if (step + 1) % 2 == 0 {
            // a flush just ran: exactly one interval outstanding, never two
            assert_eq!(t.in_flight(), interval_jobs, "step {step}");
        } else {
            assert!(t.in_flight() <= interval_jobs, "step {step}");
        }
    }
}

#[test]
fn async_offload_still_learns() {
    let mut cfg = base_cfg();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.async_offload = true;
    cfg.steps = 12;
    let l = run_losses(cfg);
    assert!(l.last().unwrap() < &l[0], "async run failed to learn: {l:?}");
}

#[test]
fn adapter_snapshot_roundtrip() {
    let mut cfg = base_cfg();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.steps = 3;
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    let p = t.adapter_snapshot(0, "l0.q").unwrap();
    assert_eq!(p.kind(), AdapterKind::LowRank);
    // after training, B must have moved off zero
    let b_norm = cola::tensor::norm(p.tensors()[1]);
    assert!(b_norm > 0.0, "adapter B still zero after training");
}
