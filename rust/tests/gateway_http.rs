//! Loopback e2e for the FTaaS gateway (`cola serve`) — the in-repo
//! mirror of the `gateway-smoke` CI job.
//!
//! The load-bearing invariant: a job submitted over HTTP produces
//! **byte-identical** loss curves and adapter bundles to the same
//! config run directly through [`Trainer`] (what `cola train` does).
//! On top of that: tenant isolation (someone else's job id is a 404,
//! not a 403), malformed requests never kill the server, and a
//! flooding tenant cannot starve another out of the admission queue.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use cola::config::{TomlDoc, TrainConfig};
use cola::coordinator::Trainer;
use cola::gateway::{client, Gateway, ServeConfig};
use cola::rng::Rng;
use cola::transport::wire;
use cola::util::json::Json;

/// The job every determinism check trains: small enough to run in a
/// test, big enough to cross several adaptation intervals and an eval.
const SMOKE_CONFIG: &str = "\
[train]
task = \"clm\"
size = \"tiny\"
method = \"cola-lowrank\"
mode = \"unmerged\"
optimizer = \"sgd\"
steps = 6
batch = 4
interval = 2
lr = 0.05
seed = 11
workers = 1
eval_every = 3
eval_batches = 2
threads = 2
";

/// A cheap config for scheduling-order tests (fairness, 429s) where
/// only *when* jobs run matters, not what they learn.
const QUICK_CONFIG: &str = "\
[train]
task = \"clm\"
size = \"tiny\"
method = \"cola-lowrank\"
mode = \"unmerged\"
optimizer = \"sgd\"
steps = 2
batch = 4
interval = 2
lr = 0.05
seed = 7
workers = 1
threads = 2
";

/// Coupled baseline: trains fine, but has no exportable adapter.
const COUPLED_CONFIG: &str = "\
[train]
task = \"clm\"
size = \"tiny\"
method = \"lora\"
mode = \"unmerged\"
optimizer = \"sgd\"
steps = 2
batch = 4
interval = 2
lr = 0.05
seed = 7
workers = 1
threads = 2
";

/// Per-test scratch path (tests share one process; pid alone is not
/// unique enough).
fn tmp_path(suffix: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cola-gw-{}-{suffix}", std::process::id()))
}

fn write_tokens(suffix: &str) -> PathBuf {
    let path = tmp_path(&format!("tokens-{suffix}"));
    std::fs::write(&path, "# gateway test tenants\nalice:tok-a\nbob:tok-b\n")
        .unwrap();
    path
}

fn gateway(suffix: &str, backlog: usize, ledger: bool, paused: bool) -> Gateway {
    let cfg = ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        token_file: write_tokens(suffix).to_string_lossy().into_owned(),
        backlog,
        ledger: if ledger {
            tmp_path(&format!("ledger-{suffix}.jsonl")).to_string_lossy().into_owned()
        } else {
            String::new()
        },
        start_paused: paused,
    };
    Gateway::bind(&cfg).unwrap()
}

fn url(addr: &str, path: &str) -> String {
    format!("http://{addr}{path}")
}

fn get(addr: &str, path: &str, token: Option<&str>) -> client::HttpResponse {
    client::request("GET", &url(addr, path), token, None).unwrap()
}

fn post(
    addr: &str,
    path: &str,
    token: Option<&str>,
    body: Option<&str>,
) -> client::HttpResponse {
    client::request(
        "POST",
        &url(addr, path),
        token,
        body.map(|b| ("application/toml", b.as_bytes())),
    )
    .unwrap()
}

/// Submit a config; returns the job id out of the 202 body.
fn submit(addr: &str, token: &str, config: &str) -> u64 {
    let resp = post(addr, "/v1/fit", Some(token), Some(config));
    assert_eq!(resp.status, 202, "{}", String::from_utf8_lossy(&resp.body));
    let obj = Json::parse(&String::from_utf8_lossy(&resp.body)).unwrap();
    obj.get("job").and_then(Json::as_f64).unwrap() as u64
}

/// Poll a job's status until it reaches a terminal state.
fn wait_done(addr: &str, token: &str, id: u64) -> Json {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let resp = get(addr, &format!("/v1/jobs/{id}"), Some(token));
        assert_eq!(resp.status, 200);
        let obj = Json::parse(&String::from_utf8_lossy(&resp.body)).unwrap();
        let state = obj.get("state").map(|s| s.to_string()).unwrap_or_default();
        if state.contains("done") || state.contains("failed") {
            return obj;
        }
        assert!(Instant::now() < deadline, "job {id} never finished: {obj}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// What `cola train` would produce for this config: the reference the
/// gateway must match byte-for-byte.
fn baseline(config: &str) -> (String, Vec<u8>) {
    let doc = TomlDoc::parse(config).unwrap();
    let cfg = TrainConfig::from_toml(&doc).unwrap();
    cfg.validate().unwrap();
    let mut trainer = Trainer::new(cfg).unwrap();
    let report = trainer.run().unwrap();
    let bundle = trainer.export_adapter_bundle().unwrap();
    (report.curves_json(), bundle)
}

#[test]
fn gateway_job_is_bitwise_identical_to_cli_train() {
    let gw = gateway("det", 8, true, false);
    let addr = gw.local_addr().to_string();
    let (base_curves, base_bundle) = baseline(SMOKE_CONFIG);

    let id = submit(&addr, "tok-a", SMOKE_CONFIG);

    // the progress stream blocks until the job is done, then closes
    // with a terminal {"done":true,...} line
    let resp = get(&addr, &format!("/v1/jobs/{id}/progress"), Some("tok-a"));
    assert_eq!(resp.status, 200);
    let text = String::from_utf8(resp.body).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    // steps=6, interval=2 -> 3 boundary observations + 1 final + done
    assert!(lines.len() >= 4, "short progress stream:\n{text}");
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("done").map(|d| d.to_string()), Some("true".into()));
    for line in &lines[..lines.len() - 1] {
        let p = Json::parse(line).unwrap();
        assert!(p.get("step").and_then(Json::as_f64).is_some(), "{line}");
        assert!(p.get("train_loss").is_some(), "{line}");
        assert!(p.get("bytes_offloaded").is_some(), "{line}");
    }

    // curves: byte-identical to what `cola train --loss_out` writes
    let resp = get(&addr, &format!("/v1/jobs/{id}/curves"), Some("tok-a"));
    assert_eq!(resp.status, 200);
    assert_eq!(String::from_utf8(resp.body).unwrap(), base_curves);

    // adapter bundle: byte-identical, and every blob decodes
    let resp = get(&addr, &format!("/v1/jobs/{id}/adapter"), Some("tok-a"));
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body, base_bundle);
    let (count, mut rest) = {
        let (head, rest) = resp.body.split_at(4);
        (u32::from_le_bytes(head.try_into().unwrap()) as usize, rest)
    };
    assert!(count > 0);
    for _ in 0..count {
        let (head, tail) = rest.split_at(4);
        let len = u32::from_le_bytes(head.try_into().unwrap()) as usize;
        let (blob, tail) = tail.split_at(len);
        let (_user, site, _adapter) = wire::decode_state(blob).unwrap();
        assert!(!site.is_empty());
        rest = tail;
    }
    assert!(rest.is_empty(), "trailing bytes after {count} blobs");

    // the usage ledger saw the run (fire-and-forget, so give the
    // writer thread a moment to drain)
    let ledger_path = tmp_path("ledger-det.jsonl");
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let text = std::fs::read_to_string(&ledger_path).unwrap_or_default();
        if text.lines().count() >= 3 {
            for line in text.lines() {
                let e = Json::parse(line).unwrap();
                assert_eq!(
                    e.get("tenant").map(|t| t.to_string()),
                    Some("\"alice\"".into())
                );
                assert!(e.get("bytes_offloaded").and_then(Json::as_f64).is_some());
            }
            break;
        }
        assert!(Instant::now() < deadline, "ledger never filled: {text:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(gw.ledger_dropped(), 0);

    let resp = post(&addr, "/v1/shutdown", Some("tok-a"), None);
    assert_eq!(resp.status, 200);
    gw.join();
}

#[test]
fn auth_and_tenant_isolation() {
    // paused: jobs stay queued, so this test never trains anything
    let gw = gateway("auth", 8, false, true);
    let addr = gw.local_addr().to_string();

    // liveness is the one unauthenticated endpoint
    let resp = get(&addr, "/healthz", None);
    assert_eq!(resp.status, 200);

    // everything else requires a valid bearer token
    assert_eq!(post(&addr, "/v1/fit", None, Some(SMOKE_CONFIG)).status, 401);
    let resp = post(&addr, "/v1/fit", Some("wrong"), Some(SMOKE_CONFIG));
    assert_eq!(resp.status, 401);
    assert!(resp.header("www-authenticate").is_some());
    assert_eq!(get(&addr, "/v1/jobs/1", Some("")).status, 401);

    // a syntactically/semantically invalid config is rejected up front
    let resp = post(&addr, "/v1/fit", Some("tok-a"), Some("steps = \"many\"\n"));
    assert_eq!(resp.status, 400);

    // wrong method on a known path
    assert_eq!(get(&addr, "/v1/fit", Some("tok-a")).status, 405);

    // alice's queued job is invisible to bob: 404, not 403
    let id = submit(&addr, "tok-a", SMOKE_CONFIG);
    assert_eq!(get(&addr, &format!("/v1/jobs/{id}"), Some("tok-a")).status, 200);
    assert_eq!(get(&addr, &format!("/v1/jobs/{id}"), Some("tok-b")).status, 404);
    let resp = get(&addr, &format!("/v1/jobs/{id}/adapter"), Some("tok-b"));
    assert_eq!(resp.status, 404);
    // artifacts before completion: conflict, not absence
    let resp = get(&addr, &format!("/v1/jobs/{id}/adapter"), Some("tok-a"));
    assert_eq!(resp.status, 409);

    // unknown resources
    assert_eq!(get(&addr, "/v1/jobs/999", Some("tok-a")).status, 404);
    assert_eq!(get(&addr, "/v1/jobs/not-a-number", Some("tok-a")).status, 404);
    assert_eq!(get(&addr, "/nope", Some("tok-a")).status, 404);

    gw.request_stop();
    gw.join();
}

#[test]
fn malformed_requests_never_kill_the_server() {
    let gw = gateway("fuzz", 8, false, true);
    let addr = gw.local_addr().to_string();

    let mut payloads: Vec<Vec<u8>> = vec![
        b"\r\n\r\n".to_vec(),
        b"GARBAGE\r\n\r\n".to_vec(),
        b"GET\r\n\r\n".to_vec(),
        b"GET / SPDY/9\r\n\r\n".to_vec(),
        b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n".to_vec(),
        b"POST /v1/fit HTTP/1.1\r\nContent-Length: banana\r\n\r\n".to_vec(),
        b"POST /v1/fit HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n".to_vec(),
        b"POST /v1/fit HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
        // request line far past the 8 KiB line cap
        {
            let mut v = b"GET /".to_vec();
            v.extend(std::iter::repeat(b'a').take(64 * 1024));
            v.extend_from_slice(b" HTTP/1.1\r\n\r\n");
            v
        },
        // header flood past the header-count cap
        {
            let mut v = b"GET /healthz HTTP/1.1\r\n".to_vec();
            for i in 0..200 {
                v.extend_from_slice(format!("X-H{i}: x\r\n").as_bytes());
            }
            v.extend_from_slice(b"\r\n");
            v
        },
        // truncated body: promises 100 bytes, sends 5, hangs up
        b"POST /v1/fit HTTP/1.1\r\nContent-Length: 100\r\n\r\nshort".to_vec(),
    ];
    // deterministic pseudo-random garbage (no external fuzzer available)
    let mut rng = Rng::new(0xC01A);
    for _ in 0..50 {
        let n = rng.below(512) + 1;
        let mut blob = Vec::with_capacity(n);
        while blob.len() < n {
            blob.extend_from_slice(&rng.next_u64().to_le_bytes());
        }
        blob.truncate(n);
        payloads.push(blob);
    }

    for payload in &payloads {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let _ = s.write_all(payload);
        let _ = s.flush();
        // half-close so the server sees EOF instead of waiting out its
        // read timeout on a request that will never complete
        let _ = s.shutdown(std::net::Shutdown::Write);
        // drain whatever error response the server sends, then hang up
        let mut sink = Vec::new();
        let _ = s.take(4096).read_to_end(&mut sink);
    }

    // the server survived all of it
    let resp = get(&addr, "/healthz", None);
    assert_eq!(resp.status, 200);

    gw.request_stop();
    gw.join();
}

#[test]
fn flooding_tenant_cannot_starve_another() {
    // paused so the admission order is fully staged before anything
    // runs — the service order is then deterministic
    let gw = gateway("fair", 4, false, true);
    let addr = gw.local_addr().to_string();

    // alice floods her whole backlog...
    let alice: Vec<u64> =
        (0..4).map(|_| submit(&addr, "tok-a", QUICK_CONFIG)).collect();
    // ...and her 5th submission bounces with 429 + Retry-After derived
    // from backlog depth x smoothed per-job runtime. The gateway is
    // paused, so no job has completed and the runtime estimate sits at
    // its 1 s/job default: the hint equals the backlog cap exactly.
    let resp = post(&addr, "/v1/fit", Some("tok-a"), Some(QUICK_CONFIG));
    assert_eq!(resp.status, 429);
    assert_eq!(resp.header("retry-after"), Some("4"));
    // bob arrives last with a single job
    let bob = submit(&addr, "tok-b", QUICK_CONFIG);

    gw.resume();
    let bob_status = wait_done(&addr, "tok-b", bob);
    for id in &alice {
        wait_done(&addr, "tok-a", *id);
    }

    // round-robin admission: alice runs first (seq 1), then bob's only
    // job (seq 2) — NOT after alice's entire backlog
    let seq = bob_status.get("started_seq").and_then(Json::as_f64).unwrap();
    assert_eq!(seq as u64, 2, "bob was starved behind the flood: {bob_status}");

    gw.request_stop();
    gw.join();
}

#[test]
fn coupled_method_has_no_adapter_to_export() {
    let gw = gateway("coupled", 8, false, false);
    let addr = gw.local_addr().to_string();

    let id = submit(&addr, "tok-a", COUPLED_CONFIG);
    let status = wait_done(&addr, "tok-a", id);
    assert!(status.to_string().contains("done"), "{status}");

    // curves exist (they are method-agnostic)...
    let resp = get(&addr, &format!("/v1/jobs/{id}/curves"), Some("tok-a"));
    assert_eq!(resp.status, 200);
    // ...but a coupled baseline keeps its tunables on the server
    let resp = get(&addr, &format!("/v1/jobs/{id}/adapter"), Some("tok-a"));
    assert_eq!(resp.status, 409);

    gw.request_stop();
    gw.join();
}
