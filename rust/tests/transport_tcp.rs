//! Loopback-TCP offload integration — the in-repo mirror of the
//! `distributed-smoke` CI job.
//!
//! The load-bearing invariant: a config trained against a `cola worker`
//! daemon over a real socket produces **bit-identical** train/eval loss
//! curves to the same config trained with in-process workers. Workers
//! run the same native kernels and the wire format round-trips every
//! f32 by bit pattern, so there is nothing for the transport to change.

use std::sync::Arc;
use std::time::Duration;

use cola::adapters::{AdapterParams, OptimizerCfg, SiteAdapter};
use cola::config::{AdapterKind, Method, Mode, OffloadTarget, Optimizer, Task,
                   TrainConfig, TransportKind};
use cola::coordinator::{FitJob, Trainer, TransferModel};
use cola::rng::Rng;
use cola::runtime::Manifest;
use cola::tensor::Tensor;
use cola::transport::tcp::{connect_with_backoff, request_daemon_shutdown,
                           TcpWorker, WorkerDaemon};
use cola::transport::{wire, Transport};

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(std::path::Path::new("artifacts")).unwrap())
}

/// Daemon on an ephemeral loopback port; returns (daemon, addr).
fn daemon() -> (WorkerDaemon, String) {
    let d = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                               manifest(), None)
        .unwrap();
    let addr = d.local_addr().to_string();
    (d, addr)
}

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.task = Task::Clm;
    cfg.size = "tiny".into();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.mode = Mode::Unmerged;
    cfg.optimizer = Optimizer::Sgd;
    cfg.steps = 6;
    cfg.interval = 2;
    cfg.eval_every = 3;
    cfg.eval_batches = 2;
    cfg.lr = 0.05;
    cfg.seed = 42;
    cfg.workers = 1;
    cfg
}

fn tcp_cfg(addr: &str) -> TrainConfig {
    let mut cfg = base_cfg();
    cfg.offload_transport = TransportKind::Tcp;
    cfg.worker_addrs = vec![addr.to_string()];
    cfg
}

#[test]
fn tcp_loopback_run_bit_identical_to_local() {
    let (d, addr) = daemon();

    let mut local = Trainer::new(base_cfg()).unwrap();
    let r_local = local.run().unwrap();

    let mut tcp = Trainer::new(tcp_cfg(&addr)).unwrap();
    let r_tcp = tcp.run().unwrap();

    // f64 == compares bit patterns here: both runs must be EXACTLY equal
    assert_eq!(r_local.train_loss.points, r_tcp.train_loss.points,
               "train curves diverged across transports");
    assert_eq!(r_local.eval_loss.points, r_tcp.eval_loss.points,
               "eval curves diverged across transports");
    assert_eq!(r_local.trainable_params, r_tcp.trainable_params);
    // adapter + optimizer state lives behind the socket, and the
    // accountant still sees it
    assert_eq!(r_local.worker_state_bytes, r_tcp.worker_state_bytes);
    assert!(r_tcp.worker_state_bytes > 0);
    // the wire actually carried the adaptation payloads
    assert!(r_tcp.timings.bytes_returned > 0);

    drop(tcp); // close the training connection before the handshake
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}

#[test]
fn tcp_merged_mode_and_snapshot_roundtrip() {
    let (d, addr) = daemon();

    let mut cfg = tcp_cfg(&addr);
    cfg.mode = Mode::Merged;
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();

    // snapshot travels back over the wire
    let p = t.adapter_snapshot(0, "l0.q").unwrap();
    assert_eq!(p.kind(), AdapterKind::LowRank);
    assert!(cola::tensor::norm(p.tensors()[1]) > 0.0,
            "adapter B still zero after TCP-offloaded training");

    drop(t);
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}

#[test]
fn daemon_state_survives_reconnect() {
    let (d, addr) = daemon();
    let mut rng = Rng::new(5);
    let params = AdapterParams::init(AdapterKind::LowRank, 8, 8, 4, 4, &mut rng);
    let adapter = SiteAdapter::new("s", params, &OptimizerCfg::sgd(0.1, 0.0));

    let w1 = TcpWorker::connect(0, &addr).unwrap();
    w1.register(3, "s", adapter).unwrap();
    let bytes = w1.state_bytes().unwrap();
    assert!(bytes > 0);
    w1.shutdown(); // drops the connection WITHOUT the shutdown handshake

    // a fresh connection sees the same daemon-resident state
    let w2 = TcpWorker::connect(1, &addr).unwrap();
    let snap = w2.snapshot(3, "s").unwrap();
    assert_eq!(snap.kind(), AdapterKind::LowRank);
    assert_eq!(w2.state_bytes().unwrap(), bytes);
    // unknown (user, site) surfaces the remote error, not a hang
    let err = w2.snapshot(9, "nope").unwrap_err();
    assert!(format!("{err:#}").contains("no adapter"), "{err:#}");
    w2.shutdown();

    request_daemon_shutdown(&addr).unwrap();
    d.join();
}

#[test]
fn connect_backoff_gives_up_with_context() {
    // port 1 on loopback: connection refused immediately, so this only
    // exercises the retry loop, not a timeout
    let err = TcpWorker::connect_with_opts(0, "127.0.0.1:1", 2,
                                           Duration::from_millis(5))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("127.0.0.1:1"), "{msg}");
    assert!(msg.contains("2 attempts"), "{msg}");
    assert!(connect_with_backoff("127.0.0.1:1", 1, Duration::from_millis(1)).is_err());
}

#[test]
fn fit_against_dead_peer_names_user_and_site() {
    // a "daemon" that answers the connect-time liveness probe, then
    // hangs up — so the link dies between connect and the first fit
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accepter = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let probe = wire::read_frame(&mut s).unwrap();
        assert!(matches!(wire::decode(&probe).unwrap(), wire::Msg::StateBytes));
        wire::send(&mut s, &wire::Msg::StateBytesOk(0)).unwrap();
        drop(s);
    });
    let w = TcpWorker::connect(0, &addr).unwrap();
    accepter.join().unwrap();

    let job = FitJob {
        user: 5,
        site: "l0.q".into(),
        x: Tensor::zeros(&[2, 4]),
        ghat: Tensor::zeros(&[2, 4]),
        grad_scale: 1.0,
        merged: false,
    };
    let rx = w.fit(job).unwrap();
    let err = rx.recv().unwrap().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("user 5"), "error must name the user: {msg}");
    assert!(msg.contains("l0.q"), "error must name the site: {msg}");
}

/// Regression: `ping` must answer within its bounded deadline even
/// while a slow fit is in flight on the same worker. The old
/// implementation enqueued the ping on the same client-thread command
/// channel as fits, so a liveness probe queued behind every in-flight
/// fit — one slow interval and the sweep judged a perfectly healthy
/// daemon dead.
#[test]
fn ping_answers_while_a_slow_fit_is_in_flight() {
    // the modeled link makes each fit occupy the daemon for ~1.5 s
    let slow = TransferModel {
        latency: Duration::from_millis(1500),
        bytes_per_sec: 1e12,
    };
    let d = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                               manifest(), Some(slow))
        .unwrap();
    let addr = d.local_addr().to_string();

    let w = TcpWorker::connect(0, &addr).unwrap();
    let mut rng = Rng::new(5);
    let params = AdapterParams::init(AdapterKind::LowRank, 8, 8, 4, 4, &mut rng);
    w.register(3, "s", SiteAdapter::new("s", params, &OptimizerCfg::sgd(0.1, 0.0)))
        .unwrap();

    let job = FitJob {
        user: 3,
        site: "s".into(),
        x: Tensor::from_fn(&[4, 8], |i| (i as f32).sin()),
        ghat: Tensor::from_fn(&[4, 8], |i| (i as f32).cos()),
        grad_scale: 1.0,
        merged: false,
    };
    let rx = w.fit(job).unwrap(); // async: the slow fit is now in flight

    let t0 = std::time::Instant::now();
    w.ping().expect("ping failed while a fit was in flight");
    assert!(
        t0.elapsed() < Duration::from_millis(1200),
        "ping took {:?} — it queued behind the in-flight fit",
        t0.elapsed()
    );

    // the slow fit still completes normally after the probe
    rx.recv().unwrap().unwrap();

    w.shutdown();
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}
