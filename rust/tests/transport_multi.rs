//! Multi-tenant daemons + batched/pipelined FitJob dispatch — the
//! in-repo mirror of the extended `distributed-smoke` CI scenarios.
//!
//! Load-bearing invariants:
//!
//! 1. One `cola worker` daemon serves N concurrent trainer connections,
//!    and per-tenant state isolation makes the shared-daemon runs
//!    **bit-identical** to dedicated-daemon runs.
//! 2. Batching (`offload_batch`) and pipelining (`offload_inflight`)
//!    change wire framing and scheduling only — loss curves stay
//!    byte-identical to the unbatched (v1-wire) run. The unbatched
//!    client emits exclusively v1 frames, so the same test pins
//!    v1-client-against-v2-daemon interop.
//! 3. Chaos: a daemon dying mid-`FitBatch` surfaces one error per lost
//!    job naming its (user, site), and the reconnect that follows
//!    replays nothing (no double-stepped optimizer).

use std::sync::Arc;
use std::time::Duration;

use cola::adapters::{AdapterParams, OptimizerCfg, SiteAdapter};
use cola::config::{AdapterKind, Method, Mode, OffloadTarget, Optimizer, Task,
                   TrainConfig, TransportKind};
use cola::coordinator::{FitJob, RunReport, Trainer};
use cola::rng::Rng;
use cola::runtime::Manifest;
use cola::tensor::Tensor;
use cola::transport::tcp::{request_daemon_shutdown, TcpLinkOpts, TcpWorker,
                           WorkerDaemon};
use cola::transport::{wire, Transport};

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(std::path::Path::new("artifacts")).unwrap())
}

/// Daemon on an ephemeral loopback port; returns (daemon, addr).
fn daemon() -> (WorkerDaemon, String) {
    let d = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                               manifest(), None)
        .unwrap();
    let addr = d.local_addr().to_string();
    (d, addr)
}

fn base_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.task = Task::Clm;
    cfg.size = "tiny".into();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.mode = Mode::Unmerged;
    cfg.optimizer = Optimizer::Sgd;
    cfg.steps = 6;
    cfg.interval = 2;
    cfg.eval_every = 3;
    cfg.eval_batches = 2;
    cfg.lr = 0.05;
    cfg.seed = seed;
    cfg.workers = 1;
    cfg
}

fn tcp_cfg(addr: &str, seed: u64, tenant: &str) -> TrainConfig {
    let mut cfg = base_cfg(seed);
    cfg.offload_transport = TransportKind::Tcp;
    cfg.worker_addrs = vec![addr.to_string()];
    cfg.offload_tenant = tenant.to_string();
    cfg
}

fn run(cfg: TrainConfig) -> RunReport {
    Trainer::new(cfg).unwrap().run().unwrap()
}

fn assert_curves_eq(a: &RunReport, b: &RunReport, what: &str) {
    // f64 == compares bit patterns here: both runs must be EXACTLY equal
    assert_eq!(a.train_loss.points, b.train_loss.points,
               "{what}: train curves diverged");
    assert_eq!(a.eval_loss.points, b.eval_loss.points,
               "{what}: eval curves diverged");
}

/// Two concurrent trainers sharing ONE daemon (distinct tenants) must
/// produce per-user loss curves bit-identical to the same two configs
/// against dedicated daemons.
#[test]
fn shared_daemon_two_concurrent_trainers_match_dedicated_daemons() {
    // baselines: each trainer gets its own daemon
    let (d_a, addr_a) = daemon();
    let (d_b, addr_b) = daemon();
    let r_a_dedicated = run(tcp_cfg(&addr_a, 42, "tenant-a"));
    let r_b_dedicated = run(tcp_cfg(&addr_b, 43, "tenant-b"));
    request_daemon_shutdown(&addr_a).unwrap();
    request_daemon_shutdown(&addr_b).unwrap();
    d_a.join();
    d_b.join();

    // the multi-tenant arrangement: both trainers, one daemon, truly
    // concurrent connections
    let (d_shared, addr) = daemon();
    let (r_a_shared, r_b_shared) = std::thread::scope(|s| {
        let addr_a = addr.clone();
        let addr_b = addr.clone();
        let ha = s.spawn(move || run(tcp_cfg(&addr_a, 42, "tenant-a")));
        let hb = s.spawn(move || run(tcp_cfg(&addr_b, 43, "tenant-b")));
        (ha.join().unwrap(), hb.join().unwrap())
    });

    assert_curves_eq(&r_a_dedicated, &r_a_shared, "trainer A (shared daemon)");
    assert_curves_eq(&r_b_dedicated, &r_b_shared, "trainer B (shared daemon)");
    // and the two tenants genuinely trained different things
    assert_ne!(r_a_shared.train_loss.points, r_b_shared.train_loss.points,
               "different seeds should not produce identical curves");

    request_daemon_shutdown(&addr).unwrap();
    d_shared.join();
}

/// Batched + pipelined dispatch must be byte-identical to the unbatched
/// seed run — and to the in-process run. The unbatched client sends
/// only v1 frames, so this is also the v1-client / v2-daemon interop
/// check.
#[test]
fn batched_pipelined_run_bit_identical_to_unbatched_and_local() {
    let r_local = run(base_cfg(42));

    let (d, addr) = daemon();
    // empty tenant + no batching = a pure v1 client: every frame it
    // sends is v1, served by the v2 daemon (interop criterion)
    let r_v1 = run(tcp_cfg(&addr, 42, ""));

    let mut batched = tcp_cfg(&addr, 42, "batched");
    batched.offload_batch = true;
    batched.offload_inflight = 2;
    let r_batched = run(batched);

    assert_curves_eq(&r_local, &r_v1, "local vs tcp");
    assert_curves_eq(&r_local, &r_batched, "local vs tcp-batched");
    // state accounting is daemon-wide across tenants: by the batched
    // run's final report the daemon holds BOTH runs' (identically
    // sized) adapter sets — the "" namespace from the v1 run and
    // "batched" — so the device footprint is exactly doubled
    assert_eq!(r_batched.worker_state_bytes, 2 * r_v1.worker_state_bytes);
    assert!(r_v1.worker_state_bytes > 0);

    // the whole point of FitBatch: fewer wire round-trips per interval
    // (tiny CLM has 4 sites -> 4 jobs/interval; the batched run ships
    // them as <= 2 pipelined frames)
    assert!(r_v1.timings.round_trips > 0);
    assert!(r_batched.timings.round_trips > 0);
    assert!(
        r_batched.timings.round_trips < r_v1.timings.round_trips,
        "batched {} vs unbatched {} round-trips",
        r_batched.timings.round_trips,
        r_v1.timings.round_trips
    );

    request_daemon_shutdown(&addr).unwrap();
    d.join();
}

fn lowrank_adapter(seed: u64) -> SiteAdapter {
    let mut rng = Rng::new(seed);
    let params = AdapterParams::init(AdapterKind::LowRank, 8, 8, 4, 4, &mut rng);
    SiteAdapter::new("s", params, &OptimizerCfg::sgd(0.1, 0.0))
}

fn job(user: usize, site: &str) -> FitJob {
    FitJob {
        user,
        site: site.to_string(),
        x: Tensor::from_fn(&[3, 8], |i| (i as f32).sin()),
        ghat: Tensor::from_fn(&[3, 8], |i| (i as f32).cos()),
        grad_scale: 1.0,
        merged: false,
    }
}

/// Chaos: the daemon dies mid-`FitBatch`. Every job in the lost batch
/// must surface its own error naming its (user, site), and the client's
/// reconnect must replay NOTHING — the next frame on the fresh
/// connection is the next request, never the lost batch.
#[test]
fn daemon_death_mid_batch_names_every_lost_job_and_replays_nothing() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        // connection 1: answer the liveness probe, read the FitBatch,
        // then die without replying (the "kill -9 mid-batch")
        let (mut s, _) = listener.accept().unwrap();
        let probe = wire::decode(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert!(matches!(probe, wire::Msg::StateBytes));
        wire::send(&mut s, &wire::Msg::StateBytesOk(0)).unwrap();
        let batch = wire::decode(&wire::read_frame(&mut s).unwrap()).unwrap();
        let wire::Msg::FitBatch { jobs, .. } = batch else {
            panic!("expected FitBatch, got {batch:?}");
        };
        assert_eq!(jobs.len(), 2);
        drop(s);

        // connection 2: the reconnect. The FIRST frame must be the next
        // request (StateBytes), not a replay of the lost batch — a
        // replay would double-step the remote optimizer.
        let (mut s, _) = listener.accept().unwrap();
        let first = wire::decode(&wire::read_frame(&mut s).unwrap()).unwrap();
        assert!(
            matches!(first, wire::Msg::StateBytes),
            "client replayed {first:?} after reconnect"
        );
        wire::send(&mut s, &wire::Msg::StateBytesOk(7)).unwrap();
    });

    let opts = TcpLinkOpts {
        attempts: 4,
        base: Duration::from_millis(5),
        batch: true,
        inflight: 1,
        ..TcpLinkOpts::default()
    };
    let w = TcpWorker::connect_with_link_opts(0, &addr, &opts).unwrap();
    let rxs = w.fit_many(vec![job(0, "l0.q"), job(1, "l0.v")]).unwrap();
    assert_eq!(rxs.len(), 2);
    let errs: Vec<String> = rxs
        .iter()
        .map(|rx| format!("{:#}", rx.recv().unwrap().unwrap_err()))
        .collect();
    assert!(errs[0].contains("user 0") && errs[0].contains("l0.q"),
            "first error must name its job: {}", errs[0]);
    assert!(errs[1].contains("user 1") && errs[1].contains("l0.v"),
            "second error must name its job: {}", errs[1]);
    for e in &errs {
        assert!(e.contains("lost in flight"), "{e}");
    }

    // next request reconnects; the fake asserts nothing was replayed
    assert_eq!(w.state_bytes().unwrap(), 7);
    fake.join().unwrap();
}

/// No double-step across disconnect/reconnect and daemon restart: a fit
/// applied once is applied exactly once — snapshots taken before and
/// after the reconnect cycle are bit-identical.
#[test]
fn reconnect_after_stop_does_not_double_step() {
    let (d, addr) = daemon();
    let opts = TcpLinkOpts {
        tenant: "t".into(),
        batch: true,
        inflight: 2,
        ..TcpLinkOpts::default()
    };
    let w = TcpWorker::connect_with_link_opts(0, &addr, &opts).unwrap();
    w.register(0, "s", lowrank_adapter(5)).unwrap();
    w.register(1, "s", lowrank_adapter(6)).unwrap();

    let rxs = w.fit_many(vec![job(0, "s"), job(1, "s")]).unwrap();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let snap0 = w.snapshot(0, "s").unwrap();
    let snap1 = w.snapshot(1, "s").unwrap();
    w.shutdown(); // drop the connection WITHOUT the shutdown handshake

    // fresh link, same tenant: state must be exactly one step advanced —
    // a replay anywhere in the reconnect path would show up here
    let w2 = TcpWorker::connect_with_link_opts(1, &addr, &opts).unwrap();
    for (user, before) in [(0, &snap0), (1, &snap1)] {
        let after = w2.snapshot(user, "s").unwrap();
        for (a, b) in before.tensors().into_iter().zip(after.tensors()) {
            assert_eq!(a, b, "user {user}: adapter moved across reconnect");
        }
    }
    // tenant isolation survives too: the default namespace sees nothing
    let w3 = TcpWorker::connect(2, &addr).unwrap();
    let err = w3.snapshot(0, "s").unwrap_err();
    assert!(format!("{err:#}").contains("no adapter"), "{err:#}");

    w2.shutdown();
    w3.shutdown();
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}

/// The daemon accepts a second connection while the first is live (the
/// multi-connection upgrade this PR exists for) — previously the second
/// link's probe would hang until the first disconnected.
#[test]
fn daemon_serves_two_links_simultaneously() {
    let (d, addr) = daemon();
    let w1 = TcpWorker::connect(0, &addr).unwrap();
    // with w1 still connected, a second link must come up and serve
    let w2 = TcpWorker::connect(1, &addr).unwrap();
    w1.register(0, "s", lowrank_adapter(1)).unwrap();
    // both links see the same (default-tenant) state
    assert_eq!(w1.state_bytes().unwrap(), w2.state_bytes().unwrap());
    assert!(w2.snapshot(0, "s").is_ok());
    w1.shutdown();
    w2.shutdown();
    request_daemon_shutdown(&addr).unwrap();
    d.join();
}
