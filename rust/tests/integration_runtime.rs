//! Runtime integration: PJRT device threads, artifact execution, and
//! native-vs-artifact equivalence of the optimizer and fit paths.

use cola::adapters::{AdapterParams, OptState, OptimizerCfg};
use cola::rng::Rng;
use cola::runtime::{Input, OutputPlan, Runtime, Value};
use cola::tensor::Tensor;

fn runtime() -> Runtime {
    Runtime::load("artifacts").expect("run `make artifacts` first")
}

#[test]
fn device_upload_read_roundtrip() {
    let rt = runtime();
    let mut rng = Rng::new(1);
    let t = Tensor::randn(&[17, 5], 1.0, &mut rng);
    rt.server.upload("x", Value::F32(t.clone())).unwrap();
    let back = rt.server.read("x").unwrap().into_f32().unwrap();
    assert_eq!(back, t);
    assert_eq!(rt.server.resident_bytes().unwrap(), t.bytes());
    rt.server.free("x").unwrap();
    assert_eq!(rt.server.resident_bytes().unwrap(), 0);
    assert!(rt.server.read("x").is_err());
}

#[test]
fn adamw_artifact_matches_native_optimizer() {
    // The lowered adamw_n64 reference and the Rust-native AdamW must
    // produce identical trajectories (workers can use either path).
    let rt = runtime();
    let mut rng = Rng::new(2);
    let n = 64;
    let mut w_native = Tensor::randn(&[n], 1.0, &mut rng);
    let mut w_art = w_native.clone();
    let mut m = Tensor::zeros(&[n]);
    let mut v = Tensor::zeros(&[n]);
    let cfg = OptimizerCfg::adamw(0.01, 0.001);
    let mut opt = OptState::new(&cfg, &[n]);

    for t in 1..=5 {
        let g = Tensor::randn(&[n], 1.0, &mut rng);
        // native
        opt.apply(&mut [&mut w_native], std::slice::from_ref(&g));
        // artifact
        let inputs = vec![
            Input::Val(Value::F32(w_art.clone())),
            Input::Val(Value::F32(g.clone())),
            Input::Val(Value::F32(m.clone())),
            Input::Val(Value::F32(v.clone())),
            Input::Val(Value::F32(Tensor::scalar(t as f32))),
            Input::Val(Value::F32(Tensor::scalar(cfg.lr))),
            Input::Val(Value::F32(Tensor::scalar(cfg.beta1))),
            Input::Val(Value::F32(Tensor::scalar(cfg.beta2))),
            Input::Val(Value::F32(Tensor::scalar(cfg.eps))),
            Input::Val(Value::F32(Tensor::scalar(cfg.weight_decay))),
        ];
        let plan = OutputPlan { keep: vec![], fetch: vec![0, 1, 2] };
        let res = rt.server.execute("adamw_n64", inputs, plan).unwrap();
        w_art = res.fetched[0].1.clone().into_f32().unwrap();
        m = res.fetched[1].1.clone().into_f32().unwrap();
        v = res.fetched[2].1.clone().into_f32().unwrap();
        assert!(
            w_native.allclose(&w_art, 1e-5, 1e-6),
            "step {t}: max diff {}",
            w_native.max_abs_diff(&w_art)
        );
    }
}

#[test]
fn sgd_artifact_matches_native() {
    let rt = runtime();
    let mut rng = Rng::new(3);
    let n = 64;
    let w0 = Tensor::randn(&[n], 1.0, &mut rng);
    let g = Tensor::randn(&[n], 1.0, &mut rng);
    let cfg = OptimizerCfg::sgd(0.05, 0.01);
    let mut w_native = w0.clone();
    let mut opt = OptState::new(&cfg, &[n]);
    opt.apply(&mut [&mut w_native], std::slice::from_ref(&g));

    let inputs = vec![
        Input::Val(Value::F32(w0)),
        Input::Val(Value::F32(g)),
        Input::Val(Value::F32(Tensor::scalar(cfg.lr))),
        Input::Val(Value::F32(Tensor::scalar(cfg.weight_decay))),
    ];
    let plan = OutputPlan { keep: vec![], fetch: vec![0] };
    let res = rt.server.execute("sgd_n64", inputs, plan).unwrap();
    let w_art = res.fetched[0].1.clone().into_f32().unwrap();
    assert!(w_native.allclose(&w_art, 1e-6, 1e-7));
}

#[test]
fn fit_artifact_matches_native_fit_grads() {
    // Property-style sweep: the Pallas fit artifact and the native Rust
    // contractions agree across random adapters/data (the two offload
    // arms are interchangeable).
    let rt = runtime();
    for seed in [1u64, 7, 23, 99] {
        let mut rng = Rng::new(seed);
        let (d, rows) = (128usize, 512usize);
        let a = Tensor::randn(&[d, 8], 0.2, &mut rng);
        let b = Tensor::randn(&[8, d], 0.2, &mut rng);
        let params = AdapterParams::LowRank { a: a.clone(), b: b.clone() };
        let x = Tensor::randn(&[rows, d], 1.0, &mut rng);
        let ghat = Tensor::randn(&[rows, d], 1.0, &mut rng);

        let native = params.fit_grads(&x, &ghat);

        let inputs = vec![
            Input::Val(Value::F32(x)),
            Input::Val(Value::F32(ghat)),
            Input::Val(Value::F32(a)),
            Input::Val(Value::F32(b)),
        ];
        let plan = OutputPlan { keep: vec![], fetch: vec![0, 1] };
        let res = rt
            .server
            .execute("fit_lowrank_128x128_n512", inputs, plan)
            .unwrap();
        let da = res.fetched[0].1.clone().into_f32().unwrap();
        let db = res.fetched[1].1.clone().into_f32().unwrap();
        assert!(native[0].allclose(&da, 1e-3, 1e-3),
                "seed {seed} dA diff {}", native[0].max_abs_diff(&da));
        assert!(native[1].allclose(&db, 1e-3, 1e-3),
                "seed {seed} dB diff {}", native[1].max_abs_diff(&db));
    }
}

#[test]
fn execute_keeps_outputs_resident() {
    let rt = runtime();
    let mut rng = Rng::new(5);
    let n = 64;
    let inputs = vec![
        Input::Val(Value::F32(Tensor::randn(&[n], 1.0, &mut rng))),
        Input::Val(Value::F32(Tensor::randn(&[n], 1.0, &mut rng))),
        Input::Val(Value::F32(Tensor::scalar(0.1))),
        Input::Val(Value::F32(Tensor::scalar(0.0))),
    ];
    let plan = OutputPlan { keep: vec![(0, "w2".into())], fetch: vec![] };
    rt.server.execute("sgd_n64", inputs, plan).unwrap();
    let kept = rt.server.read("w2").unwrap();
    assert_eq!(kept.shape(), &[n]);
}

#[test]
fn missing_artifact_is_clean_error() {
    let rt = runtime();
    let err = rt
        .server
        .execute("no_such_artifact", vec![], OutputPlan::default())
        .unwrap_err();
    assert!(format!("{err}").contains("no_such_artifact"));
}

#[test]
fn missing_resident_buffer_is_clean_error() {
    let rt = runtime();
    let inputs = vec![
        Input::Ref("nope".into()),
        Input::Val(Value::F32(Tensor::zeros(&[64]))),
        Input::Val(Value::F32(Tensor::scalar(0.1))),
        Input::Val(Value::F32(Tensor::scalar(0.0))),
    ];
    let err = rt
        .server
        .execute("sgd_n64", inputs, OutputPlan { keep: vec![], fetch: vec![0] })
        .unwrap_err();
    assert!(format!("{err}").contains("nope"));
}
