//! Task-level integration: every task family trains through the full
//! stack (artifacts -> server device -> buffers -> workers -> update).

use cola::config::{AdapterKind, Method, Mode, Task, TrainConfig};
use cola::coordinator::{Driver, FtaasService, Trainer};
use cola::runtime::Runtime;

fn cfg(task: Task, method: Method) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.task = task;
    c.size = "tiny".into();
    c.method = method;
    c.steps = 8;
    c.eval_every = 0;
    c.eval_batches = 2;
    c.lr = 1e-3;
    c
}

#[test]
fn seqcls_cola_trains_and_evaluates() {
    let mut c = cfg(Task::SeqCls, Method::Cola(AdapterKind::LowRank));
    c.dataset = "sst2".into();
    c.steps = 12;
    let mut t = Trainer::new(c).unwrap();
    let r = t.run().unwrap();
    assert!(r.train_loss.last().unwrap() < r.train_loss.points[0].1,
            "seqcls loss did not decrease");
    assert!(r.eval_acc.last().is_some());
    let acc = r.eval_acc.last().unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn seqcls_coupled_baselines_run() {
    for m in [Method::Lora, Method::Ia3] {
        let mut c = cfg(Task::SeqCls, m);
        c.dataset = "mnli".into();
        c.steps = 4;
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        assert!(r.train_loss.last().unwrap().is_finite(), "{m}");
    }
}

#[test]
fn s2s_task_trains() {
    let mut c = cfg(Task::S2s, Method::Cola(AdapterKind::Linear));
    c.dataset = "fpb".into();
    c.steps = 10;
    let mut t = Trainer::new(c).unwrap();
    let r = t.run().unwrap();
    assert!(r.train_loss.last().unwrap() < r.train_loss.points[0].1);
}

#[test]
fn clm_all_coupled_baselines_step() {
    for m in [Method::Ft, Method::Lora, Method::Ia3, Method::Prompt,
              Method::PTuning, Method::Prefix] {
        let mut c = cfg(Task::Clm, m);
        c.steps = 2;
        let mut t = Trainer::new(c).unwrap();
        let r = t.run().unwrap();
        assert!(r.train_loss.last().unwrap().is_finite(), "{m}");
        assert!(r.trainable_params > 0, "{m}");
    }
}

#[test]
fn ic_model_trains_from_scratch() {
    let rt = Runtime::load("artifacts").unwrap();
    let driver = Driver::new_ic("mlp", "smnist", 32, 0).unwrap();
    let mut c = TrainConfig::default();
    c.method = Method::Cola(AdapterKind::Linear);
    c.mode = Mode::Unmerged;
    c.steps = 15;
    c.batch = 32;
    c.lr = 0.05;
    c.optimizer = cola::config::Optimizer::Sgd;
    c.eval_every = 0;
    c.eval_batches = 2;
    let mut t = Trainer::with_driver(c, rt, driver).unwrap();
    let r = t.run().unwrap();
    let first = r.train_loss.points[0].1;
    let last = r.train_loss.last().unwrap();
    assert!(last < first, "ic loss did not decrease: {first} -> {last}");
    // accuracy should be meaningfully above chance (10%) after 15 steps
    assert!(r.eval_acc.last().unwrap() > 0.15,
            "acc {}", r.eval_acc.last().unwrap());
}

#[test]
fn ic_coupled_ft_runs() {
    let rt = Runtime::load("artifacts").unwrap();
    let driver = Driver::new_ic("linear", "smnist", 32, 1).unwrap();
    let mut c = TrainConfig::default();
    c.method = Method::Ft;
    c.steps = 10;
    c.batch = 32;
    c.lr = 0.05;
    c.optimizer = cola::config::Optimizer::Sgd;
    c.eval_every = 0;
    let mut t = Trainer::with_driver(c, rt, driver).unwrap();
    let r = t.run().unwrap();
    assert!(r.train_loss.last().unwrap() < r.train_loss.points[0].1);
}

#[test]
fn ftaas_collaboration_service() {
    let mut c = TrainConfig::default();
    c.size = "tiny".into();
    c.users = 4;
    c.batch = 8;
    c.workers = 2;
    c.steps = 1;
    c.eval_batches = 2;
    let mut svc = FtaasService::start(c, AdapterKind::LowRank).unwrap();
    assert_eq!(svc.jobs().len(), 4);
    svc.run_rounds(4).unwrap();
    let st = svc.status().unwrap();
    assert_eq!(st.rounds_completed, 4);
    assert!(st.last_train_loss.unwrap().is_finite());
    // every user can download their adapter
    for u in 0..4 {
        let p = svc.fetch_adapter(u, "l0.q").unwrap();
        assert_eq!(p.kind(), AdapterKind::LowRank);
    }
    // per-category scoring works
    let s = svc.category_score(0).unwrap();
    assert!((0.0..=100.0).contains(&s));
}

#[test]
fn multi_user_requires_merged() {
    let mut c = cfg(Task::Clm, Method::Cola(AdapterKind::LowRank));
    c.users = 2;
    c.mode = Mode::Unmerged;
    assert!(Trainer::new(c).is_err());
}

#[test]
fn bad_dataset_is_clean_error() {
    let mut c = cfg(Task::SeqCls, Method::Lora);
    c.dataset = "not-a-task".into();
    assert!(Trainer::new(c).is_err());
}
