//! Docs-stay-true test: the `cola` subcommand surface is declared once
//! (`cola::cli::SUBCOMMANDS`) and this test pins the other two copies
//! to it — the dispatch match in `src/main.rs` and the README
//! "Command reference" table. Adding a subcommand without documenting
//! it (or documenting one that doesn't exist) fails here, not in a
//! reviewer's head.

use std::collections::BTreeSet;
use std::path::Path;

fn manifest_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

/// Subcommand names as actually dispatched by `main()`: every
/// `"name" => cmd_*` arm, plus the `"" | "help"` arm.
fn dispatched_subcommands(main_src: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in main_src.lines() {
        let t = line.trim();
        if !t.contains("=> cmd_") && !t.contains("=> print_help") {
            continue;
        }
        // the arm pattern is one or more string literals before `=>`
        let Some(pat) = t.split("=>").next() else { continue };
        let mut rest = pat;
        while let Some(open) = rest.find('"') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('"') else { break };
            let name = &tail[..close];
            if !name.is_empty() {
                out.insert(name.to_string());
            }
            rest = &tail[close + 1..];
        }
    }
    // `"" | "help"` dispatches print_help via a block, not `=> cmd_*`
    if main_src.contains("\"help\"") {
        out.insert("help".to_string());
    }
    out
}

#[test]
fn dispatch_matches_the_declared_subcommand_table() {
    let main_src =
        std::fs::read_to_string(manifest_dir().join("src/main.rs")).unwrap();
    let dispatched = dispatched_subcommands(&main_src);
    let declared: BTreeSet<String> = cola::cli::SUBCOMMANDS
        .iter()
        .map(|(name, _)| name.to_string())
        .collect();
    assert_eq!(
        dispatched, declared,
        "src/main.rs dispatch and cola::cli::SUBCOMMANDS disagree — \
         update both (and the README table) together"
    );
    assert!(dispatched.len() >= 10, "suspiciously few subcommands parsed");
}

#[test]
fn readme_command_table_covers_every_subcommand() {
    let readme =
        std::fs::read_to_string(manifest_dir().join("../README.md")).unwrap();
    for (name, _) in cola::cli::SUBCOMMANDS {
        let row = format!("| `{name}` |");
        assert!(
            readme.contains(&row),
            "README.md command reference is missing a `| `{name}` |` row \
             (regenerate it from cola::cli::SUBCOMMANDS)"
        );
    }
}

#[test]
fn declared_summaries_are_nonempty_and_unique() {
    let mut names = BTreeSet::new();
    for (name, summary) in cola::cli::SUBCOMMANDS {
        assert!(!summary.is_empty(), "{name} has an empty summary");
        assert!(names.insert(name), "duplicate subcommand {name}");
    }
}
