//! Shared bench plumbing: the method grid of the paper's quality tables
//! and a uniform runner. Included by each bench via `#[path]`.

#![allow(dead_code)]

use std::sync::OnceLock;

use cola::config::{AdapterKind, Method, Mode, Task, TrainConfig};
use cola::coordinator::{Driver, RunReport, Trainer};
use cola::runtime::Runtime;

/// One shared server device for all quality arms in a bench process —
/// the backend's caches are reused (XLA executables compile once under
/// `--features xla`; the native backend shares one buffer store).
pub fn shared_runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        Runtime::load("artifacts").expect("runtime init (stale artifacts/? \
                                           delete it or re-run `make artifacts`)")
    })
}

/// The quality-table method grid (Tables 2/3/6): every coupled baseline
/// plus ColA in all adapter architectures and both modes.
pub fn method_grid() -> Vec<(String, Method, Mode)> {
    use AdapterKind::*;
    vec![
        ("FT".into(), Method::Ft, Mode::Unmerged),
        ("LoRA".into(), Method::Lora, Mode::Unmerged),
        ("IA3".into(), Method::Ia3, Mode::Unmerged),
        ("Prompt Tuning".into(), Method::Prompt, Mode::Unmerged),
        ("P-Tuning".into(), Method::PTuning, Mode::Unmerged),
        ("Prefix Tuning".into(), Method::Prefix, Mode::Unmerged),
        ("ColA (Low Rank) unmerged".into(), Method::Cola(LowRank), Mode::Unmerged),
        ("ColA (Low Rank) merged".into(), Method::Cola(LowRank), Mode::Merged),
        ("ColA (Linear) unmerged".into(), Method::Cola(Linear), Mode::Unmerged),
        ("ColA (Linear) merged".into(), Method::Cola(Linear), Mode::Merged),
        ("ColA (MLP) unmerged".into(), Method::Cola(Mlp), Mode::Unmerged),
    ]
}

/// Reduced grid for --quick runs.
pub fn quick_grid() -> Vec<(String, Method, Mode)> {
    method_grid()
        .into_iter()
        .filter(|(n, _, _)| {
            matches!(n.as_str(),
                     "LoRA" | "IA3" | "ColA (Low Rank) merged" | "ColA (Linear) merged")
        })
        .collect()
}

pub fn base_quality_cfg(task: Task, dataset: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.task = task;
    cfg.size = "tiny".into();
    cfg.dataset = dataset.into();
    cfg.steps = steps;
    cfg.interval = 1;
    cfg.eval_every = 0; // single eval at the end
    cfg.eval_batches = 8;
    cfg.workers = 2;
    cfg
}

/// Run one (method, mode) arm on the shared device; returns the report.
pub fn run_arm(mut cfg: TrainConfig, method: Method, mode: Mode)
               -> anyhow::Result<RunReport> {
    cfg = cfg.preset_for_method(method);
    cfg.mode = if method.is_cola() { mode } else { Mode::Unmerged };
    let rt = shared_runtime().clone();
    let driver = Driver::new(&cfg, &rt.manifest)?;
    let mut t = Trainer::with_driver(cfg, rt, driver)?;
    t.run()
}

pub fn fmt_params(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.1} M", n as f64 / 1e6)
    } else {
        format!("{:.1} K", n as f64 / 1e3)
    }
}

/// steps/quick from argv (benches receive `--bench` etc from cargo —
/// ignore unknown args).
pub fn bench_args() -> (usize, bool) {
    let argv: Vec<String> = std::env::args().collect();
    let quick = argv.iter().any(|a| a == "--quick")
        || std::env::var("COLA_BENCH_QUICK").is_ok();
    let steps = argv
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| argv.get(i + 1))
        .and_then(|s| s.parse().ok());
    (steps.unwrap_or(if quick { 40 } else { 60 }), quick)
}
