//! Table 1: computation-space complexity of FT / PEFT / ColA, plus the
//! byte-level instantiation on every paper-scale model profile from the
//! memory accountant. Also cross-checks the accountant's tiny-profile
//! prediction against the *measured* server residency of a real run.

use cola::bench_harness::BenchReport;
use cola::config::{AdapterKind, Method, Mode, TrainConfig};
use cola::coordinator::Trainer;
use cola::memory::{footprint, Arrangement, ModelProfile, GB};
use cola::metrics::markdown_table;

fn main() -> anyhow::Result<()> {
    let mut report = BenchReport::new("Table 1 — computation-space complexity");

    // symbolic table (the paper's Table 1)
    let rows = vec![
        vec!["FT".into(), "theta".into(), "h".into(), "grad h".into(),
             "grad theta".into()],
        vec!["PEFT unmerged".into(), "theta, w".into(), "h, h~".into(),
             "grad h, grad h~".into(), "grad w".into()],
        vec!["ColA unmerged".into(), "theta, w".into(), "h, h~".into(),
             "grad h, grad h~".into(), "{grad w}".into()],
        vec!["ColA merged".into(), "theta-hat, {w}".into(), "h, {h~}".into(),
             "grad h, {grad h~}".into(), "{grad w}".into()],
    ];
    report.section(
        "symbolic ({.} = offloadable to low-cost devices)",
        markdown_table(&["method", "params", "fwd", "bwd", "param grads"], &rows),
    );

    // byte-level instantiation on paper profiles
    use AdapterKind::*;
    for profile_name in ["roberta-base", "bart-base", "gpt2", "llama2-qv", "llama2-all"] {
        let p = ModelProfile::by_name(profile_name).unwrap();
        let mut rows = Vec::new();
        let arms: Vec<(&str, Arrangement)> = vec![
            ("FT", Arrangement::FullFt),
            ("LoRA", Arrangement::Peft { kind: LowRank, users: 1 }),
            ("ColA(LowRank) unmerged",
             Arrangement::Cola { kind: LowRank, merged: false, users: 1 }),
            ("ColA(LowRank) merged",
             Arrangement::Cola { kind: LowRank, merged: true, users: 1 }),
            ("ColA(Linear) merged",
             Arrangement::Cola { kind: Linear, merged: true, users: 1 }),
        ];
        for (label, arr) in arms {
            let fp = footprint(&p, arr, 8, 1, 8, 64);
            let server = fp.server_total() as f64 / GB;
            rows.push(vec![
                label.to_string(),
                if server > 48.0 { format!("{server:.1} (OOM>48)") }
                else { format!("{server:.1}") },
                format!("{:.1}", fp.worker_total() as f64 / GB),
            ]);
        }
        report.section(
            &format!("bytes at batch 8: {profile_name} ({} params)", p.params()),
            markdown_table(&["method", "server GB", "worker GB"], &rows),
        );
    }

    // accountant-vs-measured cross-check on the real tiny runs
    let mut rows = Vec::new();
    for (label, method, mode) in [
        ("ColA(LowRank) unmerged", Method::Cola(AdapterKind::LowRank), Mode::Unmerged),
        ("ColA(LowRank) merged", Method::Cola(AdapterKind::LowRank), Mode::Merged),
        ("ColA(Linear) unmerged", Method::Cola(AdapterKind::Linear), Mode::Unmerged),
        ("ColA(Linear) merged", Method::Cola(AdapterKind::Linear), Mode::Merged),
    ] {
        let mut cfg = TrainConfig::default();
        cfg.size = "tiny".into();
        cfg.method = method;
        cfg.mode = mode;
        cfg.steps = 2;
        cfg.eval_every = 0;
        cfg.eval_batches = 1;
        let mut t = Trainer::new(cfg)?;
        let r = t.run()?;
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", r.server_resident_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.2}", r.worker_state_bytes as f64 / (1024.0 * 1024.0)),
        ]);
    }
    report.section(
        "measured server residency, tiny profile (MiB): merged flat, unmerged grows with adapter size",
        markdown_table(&["method", "server MiB (measured)", "worker MiB"], &rows),
    );

    report.emit("table1_complexity")?;
    Ok(())
}
