//! Tables 4 & 8: user collaboration. Three arrangements over K users'
//! category-specific data:
//!   Joint         — one adapter set trained on all data mixed
//!   Alone         — each user trains separately on their own category;
//!                   the 'merged' column merges all K adapter sets
//!                   post-hoc into one model (the paper's degradation)
//!   Collaboration — K adapter sets merged into the base *during*
//!                   training (FTaaS merged mode)
//! Per-category scores of the resulting model(s).

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::{AdapterKind, Method, Mode, Task, TrainConfig};
use cola::coordinator::{FtaasService, Trainer};
use cola::data::lm::CATEGORIES;
use cola::metrics::markdown_table;

fn base_cfg(steps: usize) -> TrainConfig {
    let mut cfg = common::base_quality_cfg(Task::Clm, "dolly", steps);
    cfg.eval_batches = 6;
    cfg
}

fn main() -> anyhow::Result<()> {
    let (steps, quick) = common::bench_args();
    let users = if quick { 2 } else { 4 };
    let cats: usize = if quick { 2 } else { 8 };
    let mut report = BenchReport::new(&format!(
        "Tables 4/8 — user collaboration, {users} users, {steps} steps"));
    let mut rows = Vec::new();

    let score_all = |t: &mut Trainer| -> anyhow::Result<(Vec<f64>, f64)> {
        let mut per = Vec::new();
        for c in 0..cats {
            let (_, acc) = t.eval_category(c)?;
            per.push(acc.map(|a| a * 100.0).unwrap_or(f64::NAN));
        }
        let all = per.iter().sum::<f64>() / per.len() as f64;
        Ok((per, all))
    };

    // --- Joint: all data, one adapter set --------------------------------
    for (label, kind, mode) in [
        ("Joint LowRank unmerged", AdapterKind::LowRank, Mode::Unmerged),
        ("Joint LowRank merged", AdapterKind::LowRank, Mode::Merged),
        ("Joint Linear merged", AdapterKind::Linear, Mode::Merged),
    ] {
        let mut cfg = base_cfg(steps);
        cfg.method = Method::Cola(kind);
        cfg.mode = mode;
        let mut t = Trainer::new(cfg)?;
        t.run()?;
        let (per, all) = score_all(&mut t)?;
        println!("{label:28} all {all:.1}");
        let mut row = vec![label.to_string()];
        row.extend(per.iter().map(|s| format!("{s:.1}")));
        row.push(format!("{all:.1}"));
        rows.push(row);
    }

    // --- Alone: separate runs per user, then post-hoc merge ---------------
    {
        let mut per_alone = vec![0.0f64; cats];
        let mut merged_trainer: Option<Trainer> = None;
        for u in 0..users {
            let mut cfg = base_cfg(steps / users.max(1));
            cfg.method = Method::Cola(AdapterKind::LowRank);
            cfg.mode = Mode::Unmerged;
            cfg.dataset = CATEGORIES[u % 8].into();
            cfg.seed = u as u64;
            let mut t = Trainer::new(cfg)?;
            t.run()?;
            // own-category score of the solo model
            let (_, acc) = t.eval_category(u % 8)?;
            per_alone[u % cats] = acc.map(|a| a * 100.0).unwrap_or(f64::NAN);
            if u == users - 1 {
                // merge ALL users' adapters into the last trainer's base
                // is not possible across trainers; instead merge this
                // user's adapters post-hoc to demonstrate merge-for-
                // inference, and keep it for the 'merged' column eval.
                t.merge_user_adapters(0)?;
                merged_trainer = Some(t);
            }
        }
        let mut row = vec!["Alone LowRank (own category)".to_string()];
        for c in 0..cats {
            row.push(if per_alone[c] > 0.0 { format!("{:.1}", per_alone[c]) }
                     else { "-".into() });
        }
        let avg = per_alone.iter().filter(|s| **s > 0.0).sum::<f64>()
            / per_alone.iter().filter(|s| **s > 0.0).count().max(1) as f64;
        row.push(format!("{avg:.1}"));
        println!("{:28} own-cat avg {avg:.1}", "Alone LowRank");
        rows.push(row);

        // post-merge generalization of a solo model (degrades off-category,
        // the paper's 'Alone merged' drop)
        if let Some(mut t) = merged_trainer {
            let (per, all) = score_all(&mut t)?;
            let mut row = vec!["Alone LowRank merged-for-inference".to_string()];
            row.extend(per.iter().map(|s| format!("{s:.1}")));
            row.push(format!("{all:.1}"));
            println!("{:28} all {all:.1}", "Alone merged");
            rows.push(row);
        }
    }

    // --- Collaboration: K users, merged during training -------------------
    for (label, kind) in [("Collab LowRank", AdapterKind::LowRank),
                          ("Collab Linear", AdapterKind::Linear)] {
        let mut cfg = base_cfg(steps);
        cfg.users = users;
        cfg.batch = 8;
        cfg.workers = users.min(4);
        let mut svc = FtaasService::start(cfg, kind)?;
        svc.run_rounds(steps as u64)?;
        let mut per = Vec::new();
        for c in 0..cats {
            per.push(svc.category_score(c)?);
        }
        let all = per.iter().sum::<f64>() / per.len() as f64;
        println!("{label:28} all {all:.1}");
        let mut row = vec![label.to_string()];
        row.extend(per.iter().map(|s| format!("{s:.1}")));
        row.push(format!("{all:.1}"));
        rows.push(row);
    }

    let mut headers: Vec<String> = vec!["Arrangement".into()];
    headers.extend((0..cats).map(|c| CATEGORIES[c].to_string()));
    headers.push("All".into());
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    report.section("per-category token acc x100", markdown_table(&hrefs, &rows));
    report.emit("table4_collab")?;
    Ok(())
}
