//! Tables 10-18: computation evaluation.
//!
//! Two halves, mirroring the paper:
//!  (a) **measured** — real step-time breakdowns on the tiny profile at
//!      batch {1, 8, 32} for LoRA / FT / ColA x {unmerged, merged} x
//!      offload {cpu-native, pjrt-device}, plus the K=8 collaboration
//!      arm (Tables 16-18 trend) — run on this testbed's server device;
//!  (b) **analytic** — the byte ledger instantiated on the paper's
//!      RoBERTa/BART/GPT-2/Llama-2 profiles (what needs an A6000),
//!      reproducing who fits in 48 GB and what grows with K.

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::{AdapterKind, Method, Mode, OffloadTarget, Task, TrainConfig};
use cola::coordinator::Trainer;
use cola::memory::{footprint, Arrangement, ModelProfile, GB};
use cola::metrics::markdown_table;

fn measured_row(label: &str, mut cfg: TrainConfig)
                -> anyhow::Result<Vec<String>> {
    cfg.steps = 10;
    cfg.eval_every = 0;
    cfg.eval_batches = 1;
    let mut t = Trainer::new(cfg)?;
    let r = t.run()?;
    let tm = &r.timings;
    Ok(vec![
        label.to_string(),
        format!("{:.2}", r.server_resident_bytes as f64 / (1024.0 * 1024.0)),
        format!("{:.4}", tm.per_step(tm.fwdbwd)),
        format!("{:.4}", tm.per_step(tm.transfer)),
        format!("{:.4}", tm.per_step(tm.worker)),
        format!("{:.1}", tm.bytes_offloaded as f64 / (1024.0 * 1024.0)
                / tm.steps as f64),
    ])
}

fn main() -> anyhow::Result<()> {
    let (_steps, quick) = common::bench_args();
    let mut report = BenchReport::new("Tables 10-18 — computation evaluation");

    // (a) measured, batch sweep
    let batches: &[usize] = if quick { &[8] } else { &[1, 8, 32] };
    for &b in batches {
        let mut rows = Vec::new();
        let base = || {
            let mut c = TrainConfig::default();
            c.task = Task::Clm;
            c.size = "tiny".into();
            c.batch = b;
            c.workers = 2;
            c
        };
        let mut c = base();
        c.method = Method::Ft;
        rows.push(measured_row("FT (coupled)", c)?);
        let mut c = base();
        c.method = Method::Lora;
        rows.push(measured_row("LoRA (coupled)", c)?);
        for (label, mode, offload) in [
            ("ColA LowRank unmerged / cpu", Mode::Unmerged, OffloadTarget::NativeCpu),
            ("ColA LowRank unmerged / gpu-dev", Mode::Unmerged, OffloadTarget::PjrtDevice),
            ("ColA LowRank merged / cpu", Mode::Merged, OffloadTarget::NativeCpu),
        ] {
            let mut c = base();
            c.method = Method::Cola(AdapterKind::LowRank);
            c.mode = mode;
            c.offload = offload;
            rows.push(measured_row(label, c)?);
        }
        report.section(
            &format!("measured, tiny profile, batch {b} (s/step; offload MiB/step)"),
            markdown_table(&["method", "server MiB", "base s", "transfer s",
                             "worker s", "offload MiB"], &rows));
    }

    // (a2) K-user collaboration residency (Tables 16-18 trend)
    if !quick {
        let mut rows = Vec::new();
        for users in [1usize, 2, 4, 8] {
            let mut c = TrainConfig::default();
            c.task = Task::Clm;
            c.size = "tiny".into();
            c.users = users;
            c.batch = 8;
            c.workers = users.min(4);
            c.method = Method::Cola(AdapterKind::LowRank);
            c.mode = Mode::Merged;
            c.dataset = "per-user".into();
            c.steps = 6;
            c.eval_every = 0;
            c.eval_batches = 1;
            let mut t = Trainer::new(c)?;
            let r = t.run()?;
            rows.push(vec![
                format!("{users}"),
                format!("{:.2}", r.server_resident_bytes as f64 / (1024.0 * 1024.0)),
                format!("{:.2}", r.worker_state_bytes as f64 / (1024.0 * 1024.0)),
                format!("{:.4}", r.timings.per_step(r.timings.fwdbwd)),
            ]);
        }
        report.section(
            "measured: merged-mode server residency vs number of users K \
             (flat server column = Tables 16-18 headline)",
            markdown_table(&["users K", "server MiB", "worker MiB", "base s/step"],
                           &rows));
    }

    // (b) analytic paper-scale tables
    use AdapterKind::*;
    for (profile_name, table) in [("roberta-base", "Table 10"),
                                  ("bart-base", "Table 11"),
                                  ("gpt2", "Table 12"),
                                  ("llama2-qv", "Table 13"),
                                  ("llama2-all", "Table 14")] {
        let p = ModelProfile::by_name(profile_name).unwrap();
        let mut rows = Vec::new();
        for &b in &[1usize, 8, 32] {
            let arms: Vec<(String, Arrangement)> = vec![
                (format!("b{b} FT"), Arrangement::FullFt),
                (format!("b{b} LoRA"), Arrangement::Peft { kind: LowRank, users: 1 }),
                (format!("b{b} ColA LowRank unmerged"),
                 Arrangement::Cola { kind: LowRank, merged: false, users: 1 }),
                (format!("b{b} ColA LowRank merged"),
                 Arrangement::Cola { kind: LowRank, merged: true, users: 1 }),
                (format!("b{b} ColA Linear merged"),
                 Arrangement::Cola { kind: Linear, merged: true, users: 1 }),
            ];
            for (label, arr) in arms {
                let fp = footprint(&p, arr, b, 1, 8, 64);
                let server = fp.server_total() as f64 / GB;
                rows.push(vec![
                    label,
                    if server > 48.0 { format!("{server:.1} — OOM") }
                    else { format!("{server:.1}") },
                    format!("{:.2}", fp.worker_total() as f64 / GB),
                    format!("{:.3}", fp.transfer_per_step as f64 / GB),
                ]);
            }
        }
        report.section(
            &format!("{table} analytic: {profile_name} on a 48 GB device"),
            markdown_table(&["arrangement", "server GB", "worker GB",
                             "transfer GB/step"], &rows));
    }

    report.emit("table10_compute")?;
    Ok(())
}
