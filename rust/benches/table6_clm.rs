//! Tables 6 & 7: causal language modeling on the instruction mix
//! (Dolly substitute). Table 6 arm = tiny profile (GPT-2 stand-in) with
//! the full method grid; Table 7 arm = small profile (Llama-2 stand-in,
//! ColA + LoRA-class methods). Curves -> Fig 17 CSV.

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::{AdapterKind, Method, Mode, Task};
use cola::metrics::{curves_to_csv, markdown_table, Curve};

fn main() -> anyhow::Result<()> {
    let (steps, quick) = common::bench_args();
    let mut report = BenchReport::new(&format!(
        "Tables 6-7 — causal LM instruction tuning, {steps} steps"));
    let mut curves: Vec<Curve> = Vec::new();

    // Table 6: tiny (GPT-2 stand-in), full grid
    let grid = if quick { common::quick_grid() } else { common::method_grid() };
    let mut rows = Vec::new();
    for (label, method, mode) in &grid {
        let mut cfg = common::base_quality_cfg(Task::Clm, "dolly", steps);
        cfg.eval_every = (steps / 6).max(1);
        let r = common::run_arm(cfg, *method, *mode)?;
        println!("[tiny ] {label:32} {:.1}", r.score());
        rows.push(vec![label.clone(), common::fmt_params(r.trainable_params),
                       format!("{:.1}", r.score())]);
        let mut c = r.eval_acc.clone();
        c.name = format!("tiny/{label}");
        curves.push(c);
    }
    report.section("Table 6 (GPT-2 stand-in = tiny): token acc x100 on Dolly substitute",
                   markdown_table(&["Method", "Trainable", "Score"], &rows));

    // Table 7: small (Llama-2 stand-in), ColA arms + LoRA
    if !quick {
        let arms: Vec<(&str, Method, Mode)> = vec![
            ("ColA (Low Rank) unmerged", Method::Cola(AdapterKind::LowRank), Mode::Unmerged),
            ("ColA (Low Rank) merged", Method::Cola(AdapterKind::LowRank), Mode::Merged),
            ("ColA (Linear) merged", Method::Cola(AdapterKind::Linear), Mode::Merged),
            ("ColA (MLP) unmerged", Method::Cola(AdapterKind::Mlp), Mode::Unmerged),
        ];
        let mut rows = Vec::new();
        let small_steps = steps / 2; // larger model, half the budget
        for (label, method, mode) in arms {
            let mut cfg = common::base_quality_cfg(Task::Clm, "dolly", small_steps);
            cfg.size = "small".into();
            cfg.eval_every = (small_steps / 4).max(1);
            let r = common::run_arm(cfg, method, mode)?;
            println!("[small] {label:32} {:.1}", r.score());
            rows.push(vec![label.to_string(),
                           common::fmt_params(r.trainable_params),
                           format!("{:.1}", r.score())]);
            let mut c = r.eval_acc.clone();
            c.name = format!("small/{label}");
            curves.push(c);
        }
        report.section(
            "Table 7 (Llama-2 stand-in = small): ColA arms",
            markdown_table(&["Method", "Trainable", "Score"], &rows));
    }

    report.emit("table6_clm")?;
    let refs: Vec<&Curve> = curves.iter().collect();
    report.write_csv("fig17_clm_curves", &curves_to_csv(&refs))?;
    Ok(())
}
