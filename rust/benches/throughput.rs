//! Native tensor-engine throughput baseline (EXPERIMENTS.md §Perf).
//!
//! Measures GFLOP/s for the matmul family at bench sizes — single-thread
//! vs the full scoped-thread pool — plus end-to-end decoupled-step
//! throughput (steps/sec) on the tiny/small LM graphs, and emits a
//! machine-readable baseline to `BENCH_throughput.json` (override with
//! `COLA_BENCH_OUT`). CI runs `--quick` and gates on
//! `COLA_BENCH_MIN_SPEEDUP` so engine regressions fail loudly.
//!
//! Target (acceptance): >= 3x single-thread matmul throughput on >= 4
//! cores at the non-quick bench sizes.

#[path = "common.rs"]
mod common;

use std::collections::BTreeMap;

use cola::bench_harness::{bench, BenchReport, BenchStats};
use cola::config::{AdapterKind, Method, Mode, Task, TrainConfig, WireFormat};
use cola::coordinator::{FitJob, Trainer};
use cola::metrics::markdown_table;
use cola::rng::Rng;
use cola::tensor::{self, pool, simd, Tensor};
use cola::transport::wire::{self, Msg};
use cola::util::json::Json;

fn gflops(flops: f64, s: &BenchStats) -> f64 {
    flops / s.median.as_secs_f64().max(1e-12) / 1e9
}

/// (single-thread GFLOP/s, full-pool GFLOP/s) for one kernel closure.
fn measure(iters: usize, flops: f64, f: impl Fn() -> Tensor) -> (f64, f64) {
    pool::set_threads(1);
    let s1 = bench("single", 1, iters, &f);
    pool::set_threads(0); // back to COLA_THREADS/auto
    let sn = bench("multi", 1, iters, &f);
    (gflops(flops, &s1), gflops(flops, &sn))
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() -> anyhow::Result<()> {
    let (_steps, quick) = common::bench_args();
    let iters = if quick { 3 } else { 5 };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let cases: &[(&str, usize, usize, usize)] = if quick {
        &[
            ("square_192", 192, 192, 192),
            ("adapter_fit_2048x128", 2048, 128, 128),
        ]
    } else {
        &[
            ("square_256", 256, 256, 256),
            ("square_384", 384, 384, 384),
            ("adapter_fit_4096x128", 4096, 128, 128),
            ("skinny_lora_4096x128x8", 4096, 128, 8),
        ]
    };

    let mut report = BenchReport::new("Tensor-engine throughput");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut mm_json = Vec::new();
    let mut best_speedup = 0.0f64;
    // the CI gate tracks the *worst* matmul-kernel speedup across cases:
    // a max over all kernels would stay green while matmul itself
    // regressed to serial
    let mut matmul_min_speedup = f64::INFINITY;
    for &(name, m, k, n) in cases {
        let mut rng = Rng::new(7);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let at = tensor::transpose(&a);
        let bt = tensor::transpose(&b);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;

        let (s_mm, n_mm) = measure(iters, flops, || tensor::matmul(&a, &b));
        let (s_tn, n_tn) = measure(iters, flops, || tensor::matmul_tn(&at, &b));
        let (s_nt, n_nt) = measure(iters, flops, || tensor::matmul_nt(&a, &bt));
        for (kernel, single, multi) in [
            ("matmul", s_mm, n_mm),
            ("matmul_tn", s_tn, n_tn),
            ("matmul_nt", s_nt, n_nt),
        ] {
            let speedup = multi / single.max(1e-12);
            best_speedup = best_speedup.max(speedup);
            if kernel == "matmul" {
                matmul_min_speedup = matmul_min_speedup.min(speedup);
            }
            let mut o = BTreeMap::new();
            o.insert("case".to_string(), Json::Str(name.to_string()));
            o.insert("kernel".to_string(), Json::Str(kernel.to_string()));
            o.insert("m".to_string(), num(m as f64));
            o.insert("k".to_string(), num(k as f64));
            o.insert("n".to_string(), num(n as f64));
            o.insert("single_gflops".to_string(), num(single));
            o.insert("multi_gflops".to_string(), num(multi));
            o.insert("speedup".to_string(), num(speedup));
            mm_json.push(Json::Obj(o));
            rows.push(vec![
                format!("{name}/{kernel}"),
                format!("{m}x{k}x{n}"),
                format!("{single:.2}"),
                format!("{multi:.2}"),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    report.section(
        &format!("matmul family, {cores} cores (GFLOP/s)"),
        markdown_table(
            &["kernel", "shape", "1-thread", "pool", "speedup"],
            &rows,
        ),
    );

    // kernel dispatch tiers: the same matmul cases single-threaded,
    // scalar vs the runtime-detected vector path vs opt-in FMA — the
    // scalar-vs-SIMD GFLOP/s trajectory in EXPERIMENTS.md. Reported,
    // not gated: a CI container without AVX2 legitimately shows 1.0x.
    let detected = {
        simd::set_policy(Some(simd::Policy::Auto));
        simd::describe()
    };
    let mut simd_rows: Vec<Vec<String>> = Vec::new();
    let mut simd_json = Vec::new();
    let mut simd_min_speedup = f64::INFINITY;
    pool::set_threads(1);
    for &(name, m, k, n) in cases {
        let mut rng = Rng::new(7);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        simd::set_policy(Some(simd::Policy::Off));
        let sc = gflops(flops, &bench("scalar", 1, iters, || tensor::matmul(&a, &b)));
        simd::set_policy(Some(simd::Policy::Auto));
        let vg = gflops(flops, &bench("simd", 1, iters, || tensor::matmul(&a, &b)));
        simd::set_policy(Some(simd::Policy::Fma));
        let fg = gflops(flops, &bench("fma", 1, iters, || tensor::matmul(&a, &b)));
        let speedup = vg / sc.max(1e-12);
        simd_min_speedup = simd_min_speedup.min(speedup);
        let mut o = BTreeMap::new();
        o.insert("case".to_string(), Json::Str(name.to_string()));
        o.insert("scalar_gflops".to_string(), num(sc));
        o.insert("simd_gflops".to_string(), num(vg));
        o.insert("fma_gflops".to_string(), num(fg));
        o.insert("simd_speedup".to_string(), num(speedup));
        simd_json.push(Json::Obj(o));
        simd_rows.push(vec![
            name.to_string(),
            format!("{sc:.2}"),
            format!("{vg:.2}"),
            format!("{fg:.2}"),
            format!("{speedup:.2}x"),
        ]);
    }
    simd::set_policy(None); // back to the COLA_SIMD env decision
    pool::set_threads(0);
    report.section(
        &format!("SIMD kernel tiers, 1 thread, detected level `{detected}` (GFLOP/s)"),
        markdown_table(
            &["case", "scalar", "simd", "fma", "simd speedup"],
            &simd_rows,
        ),
    );

    // end-to-end decoupled steps/sec (server fwd/bwd + offload fit),
    // native backend, full pool
    let lm_sizes: &[&str] = if quick { &["tiny"] } else { &["tiny", "small"] };
    let mut lm_rows: Vec<Vec<String>> = Vec::new();
    let mut lm_json = BTreeMap::new();
    for &size in lm_sizes {
        let mut cfg = TrainConfig::default();
        cfg.task = Task::Clm;
        cfg.size = size.into();
        cfg.method = Method::Cola(AdapterKind::LowRank);
        cfg.mode = Mode::Unmerged;
        cfg.eval_every = 0;
        cfg.eval_batches = 1;
        cfg.workers = 2;
        let mut t = Trainer::new(cfg)?;
        let st = bench(
            &format!("lm_{size}"),
            1,
            if quick { 3 } else { 6 },
            || t.step(0).unwrap(),
        );
        let sps = 1.0 / st.median.as_secs_f64().max(1e-12);
        lm_json.insert(size.to_string(), num(sps));
        lm_rows.push(vec![
            size.to_string(),
            format!("{:.4}", st.median.as_secs_f64()),
            format!("{sps:.2}"),
        ]);
    }
    report.section(
        "decoupled LM step throughput (ColA LowRank unmerged, native)",
        markdown_table(&["size", "s/step (median)", "steps/sec"], &lm_rows),
    );

    // wire bytes/interval: frame the same FitBatch an offloading
    // interval ships, once per `offload_wire` encoding, and count the
    // actual bytes (headers included) via the real send path. No
    // sockets needed — the encoding is a pure function of the message.
    // Shapes mirror the distributed-smoke config (batch 8, interval 2,
    // tiny model) plus one base-model-sized shape.
    let wire_cases: &[(&str, usize, usize, usize)] = &[
        // (label, jobs per interval, rows = batch * interval, width)
        ("smoke_tiny_4x16x64", 4, 16, 64),
        ("base_8x64x512", 8, 64, 512),
    ];
    let mut wire_rows: Vec<Vec<String>> = Vec::new();
    let mut wire_json = Vec::new();
    let (mut total_f32, mut total_bf16) = (0u64, 0u64);
    for &(label, jobs, rows_n, width) in wire_cases {
        let mut rng = Rng::new(0xC01A);
        let jobs: Vec<FitJob> = (0..jobs)
            .map(|u| FitJob {
                user: u,
                site: format!("blocks.{u}.attn"),
                x: Tensor::randn(&[rows_n, width], 1.0, &mut rng),
                ghat: Tensor::randn(&[rows_n, width], 1.0, &mut rng),
                grad_scale: 0.5,
                merged: false,
            })
            .collect();
        let msg = Msg::FitBatch { seq: 1, jobs };
        let mut sink = Vec::new();
        let f32_bytes = wire::send_with(&mut sink, &msg, WireFormat::F32)? as u64;
        sink.clear();
        let bf16_bytes = wire::send_with(&mut sink, &msg, WireFormat::Bf16)? as u64;
        total_f32 += f32_bytes;
        total_bf16 += bf16_bytes;
        let saving = 100.0 * (1.0 - bf16_bytes as f64 / f32_bytes as f64);
        let mut o = BTreeMap::new();
        o.insert("case".to_string(), Json::Str(label.to_string()));
        o.insert("bytes_f32".to_string(), num(f32_bytes as f64));
        o.insert("bytes_bf16".to_string(), num(bf16_bytes as f64));
        o.insert("saving_pct".to_string(), num(saving));
        wire_json.push(Json::Obj(o));
        wire_rows.push(vec![
            label.to_string(),
            format!("{f32_bytes}"),
            format!("{bf16_bytes}"),
            format!("{saving:.1}%"),
        ]);
    }
    let wire_saving_pct = 100.0 * (1.0 - total_bf16 as f64 / total_f32 as f64);
    report.section(
        "wire bytes per FitBatch interval (f32 vs bf16)",
        markdown_table(
            &["case", "f32 bytes", "bf16 bytes", "saving"],
            &wire_rows,
        ),
    );
    report.emit("throughput")?;

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("throughput".to_string()));
    top.insert("schema".to_string(), num(1.0));
    top.insert("quick".to_string(), Json::Bool(quick));
    top.insert("cores".to_string(), num(cores as f64));
    top.insert("threads".to_string(), num(pool::max_threads() as f64));
    top.insert("matmul".to_string(), Json::Arr(mm_json));
    top.insert("simd_level".to_string(), Json::Str(detected.to_string()));
    top.insert("simd_matmul".to_string(), Json::Arr(simd_json));
    top.insert("simd_min_speedup".to_string(), num(simd_min_speedup));
    top.insert("lm_steps_per_sec".to_string(), Json::Obj(lm_json));
    top.insert("best_matmul_speedup".to_string(), num(best_speedup));
    top.insert("matmul_min_speedup".to_string(), num(matmul_min_speedup));
    // cargo runs bench binaries with cwd = the package root (rust/);
    // the tracked baseline lives at the workspace root one level up
    let out = std::env::var("COLA_BENCH_OUT").unwrap_or_else(|_| {
        match std::env::var("CARGO_MANIFEST_DIR") {
            Ok(dir) => format!("{dir}/../BENCH_throughput.json"),
            Err(_) => "BENCH_throughput.json".to_string(),
        }
    });
    std::fs::write(&out, format!("{}\n", Json::Obj(top)))?;
    println!(
        "wrote {out} (matmul speedup min {matmul_min_speedup:.2}x / \
         best overall {best_speedup:.2}x on {cores} cores)"
    );

    if let Ok(raw) = std::env::var("COLA_BENCH_MIN_SPEEDUP") {
        // a malformed threshold must not silently disable the gate
        let minv: f64 = match raw.parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("COLA_BENCH_MIN_SPEEDUP={raw:?} is not a number");
                std::process::exit(1);
            }
        };
        if matmul_min_speedup < minv {
            eprintln!(
                "PERF REGRESSION: worst-case matmul speedup \
                 {matmul_min_speedup:.2}x < required {minv:.2}x ({cores} cores)"
            );
            std::process::exit(1);
        }
    }

    // machine-readable wire baseline, same pattern as the throughput
    // JSON: CI uploads it as an artifact and gates bf16 on a minimum
    // bytes/interval saving
    let mut wt = BTreeMap::new();
    wt.insert("bench".to_string(), Json::Str("wire".to_string()));
    wt.insert("schema".to_string(), num(1.0));
    wt.insert("cases".to_string(), Json::Arr(wire_json));
    wt.insert("total_bytes_f32".to_string(), num(total_f32 as f64));
    wt.insert("total_bytes_bf16".to_string(), num(total_bf16 as f64));
    wt.insert("saving_pct".to_string(), num(wire_saving_pct));
    let wire_out = std::env::var("COLA_BENCH_WIRE_OUT").unwrap_or_else(|_| {
        match std::env::var("CARGO_MANIFEST_DIR") {
            Ok(dir) => format!("{dir}/../BENCH_wire.json"),
            Err(_) => "BENCH_wire.json".to_string(),
        }
    });
    std::fs::write(&wire_out, format!("{}\n", Json::Obj(wt)))?;
    println!(
        "wrote {wire_out} (bf16 saves {wire_saving_pct:.1}% of FitBatch \
         bytes/interval: {total_f32} -> {total_bf16})"
    );

    if let Ok(raw) = std::env::var("COLA_BENCH_MIN_WIRE_SAVING") {
        // same loud-threshold contract as COLA_BENCH_MIN_SPEEDUP
        let minv: f64 = match raw.parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("COLA_BENCH_MIN_WIRE_SAVING={raw:?} is not a number");
                std::process::exit(1);
            }
        };
        if wire_saving_pct < minv {
            eprintln!(
                "WIRE REGRESSION: bf16 saves only {wire_saving_pct:.1}% of \
                 FitBatch bytes/interval, required >= {minv:.1}%"
            );
            std::process::exit(1);
        }
    }
    Ok(())
}
