//! Native tensor-engine throughput baseline (EXPERIMENTS.md §Perf).
//!
//! Measures GFLOP/s for the matmul family at bench sizes — single-thread
//! vs the full scoped-thread pool — plus end-to-end decoupled-step
//! throughput (steps/sec) on the tiny/small LM graphs, and emits a
//! machine-readable baseline to `BENCH_throughput.json` (override with
//! `COLA_BENCH_OUT`). CI runs `--quick` and gates on
//! `COLA_BENCH_MIN_SPEEDUP` so engine regressions fail loudly.
//!
//! Target (acceptance): >= 3x single-thread matmul throughput on >= 4
//! cores at the non-quick bench sizes.

#[path = "common.rs"]
mod common;

use std::collections::BTreeMap;

use cola::bench_harness::{bench, BenchReport, BenchStats};
use cola::config::{AdapterKind, Method, Mode, Task, TrainConfig};
use cola::coordinator::Trainer;
use cola::metrics::markdown_table;
use cola::rng::Rng;
use cola::tensor::{self, pool, Tensor};
use cola::util::json::Json;

fn gflops(flops: f64, s: &BenchStats) -> f64 {
    flops / s.median.as_secs_f64().max(1e-12) / 1e9
}

/// (single-thread GFLOP/s, full-pool GFLOP/s) for one kernel closure.
fn measure(iters: usize, flops: f64, f: impl Fn() -> Tensor) -> (f64, f64) {
    pool::set_threads(1);
    let s1 = bench("single", 1, iters, &f);
    pool::set_threads(0); // back to COLA_THREADS/auto
    let sn = bench("multi", 1, iters, &f);
    (gflops(flops, &s1), gflops(flops, &sn))
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() -> anyhow::Result<()> {
    let (_steps, quick) = common::bench_args();
    let iters = if quick { 3 } else { 5 };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let cases: &[(&str, usize, usize, usize)] = if quick {
        &[
            ("square_192", 192, 192, 192),
            ("adapter_fit_2048x128", 2048, 128, 128),
        ]
    } else {
        &[
            ("square_256", 256, 256, 256),
            ("square_384", 384, 384, 384),
            ("adapter_fit_4096x128", 4096, 128, 128),
            ("skinny_lora_4096x128x8", 4096, 128, 8),
        ]
    };

    let mut report = BenchReport::new("Tensor-engine throughput");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut mm_json = Vec::new();
    let mut best_speedup = 0.0f64;
    // the CI gate tracks the *worst* matmul-kernel speedup across cases:
    // a max over all kernels would stay green while matmul itself
    // regressed to serial
    let mut matmul_min_speedup = f64::INFINITY;
    for &(name, m, k, n) in cases {
        let mut rng = Rng::new(7);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let at = tensor::transpose(&a);
        let bt = tensor::transpose(&b);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;

        let (s_mm, n_mm) = measure(iters, flops, || tensor::matmul(&a, &b));
        let (s_tn, n_tn) = measure(iters, flops, || tensor::matmul_tn(&at, &b));
        let (s_nt, n_nt) = measure(iters, flops, || tensor::matmul_nt(&a, &bt));
        for (kernel, single, multi) in [
            ("matmul", s_mm, n_mm),
            ("matmul_tn", s_tn, n_tn),
            ("matmul_nt", s_nt, n_nt),
        ] {
            let speedup = multi / single.max(1e-12);
            best_speedup = best_speedup.max(speedup);
            if kernel == "matmul" {
                matmul_min_speedup = matmul_min_speedup.min(speedup);
            }
            let mut o = BTreeMap::new();
            o.insert("case".to_string(), Json::Str(name.to_string()));
            o.insert("kernel".to_string(), Json::Str(kernel.to_string()));
            o.insert("m".to_string(), num(m as f64));
            o.insert("k".to_string(), num(k as f64));
            o.insert("n".to_string(), num(n as f64));
            o.insert("single_gflops".to_string(), num(single));
            o.insert("multi_gflops".to_string(), num(multi));
            o.insert("speedup".to_string(), num(speedup));
            mm_json.push(Json::Obj(o));
            rows.push(vec![
                format!("{name}/{kernel}"),
                format!("{m}x{k}x{n}"),
                format!("{single:.2}"),
                format!("{multi:.2}"),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    report.section(
        &format!("matmul family, {cores} cores (GFLOP/s)"),
        markdown_table(
            &["kernel", "shape", "1-thread", "pool", "speedup"],
            &rows,
        ),
    );

    // end-to-end decoupled steps/sec (server fwd/bwd + offload fit),
    // native backend, full pool
    let lm_sizes: &[&str] = if quick { &["tiny"] } else { &["tiny", "small"] };
    let mut lm_rows: Vec<Vec<String>> = Vec::new();
    let mut lm_json = BTreeMap::new();
    for &size in lm_sizes {
        let mut cfg = TrainConfig::default();
        cfg.task = Task::Clm;
        cfg.size = size.into();
        cfg.method = Method::Cola(AdapterKind::LowRank);
        cfg.mode = Mode::Unmerged;
        cfg.eval_every = 0;
        cfg.eval_batches = 1;
        cfg.workers = 2;
        let mut t = Trainer::new(cfg)?;
        let st = bench(
            &format!("lm_{size}"),
            1,
            if quick { 3 } else { 6 },
            || t.step(0).unwrap(),
        );
        let sps = 1.0 / st.median.as_secs_f64().max(1e-12);
        lm_json.insert(size.to_string(), num(sps));
        lm_rows.push(vec![
            size.to_string(),
            format!("{:.4}", st.median.as_secs_f64()),
            format!("{sps:.2}"),
        ]);
    }
    report.section(
        "decoupled LM step throughput (ColA LowRank unmerged, native)",
        markdown_table(&["size", "s/step (median)", "steps/sec"], &lm_rows),
    );
    report.emit("throughput")?;

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("throughput".to_string()));
    top.insert("schema".to_string(), num(1.0));
    top.insert("quick".to_string(), Json::Bool(quick));
    top.insert("cores".to_string(), num(cores as f64));
    top.insert("threads".to_string(), num(pool::max_threads() as f64));
    top.insert("matmul".to_string(), Json::Arr(mm_json));
    top.insert("lm_steps_per_sec".to_string(), Json::Obj(lm_json));
    top.insert("best_matmul_speedup".to_string(), num(best_speedup));
    top.insert("matmul_min_speedup".to_string(), num(matmul_min_speedup));
    // cargo runs bench binaries with cwd = the package root (rust/);
    // the tracked baseline lives at the workspace root one level up
    let out = std::env::var("COLA_BENCH_OUT").unwrap_or_else(|_| {
        match std::env::var("CARGO_MANIFEST_DIR") {
            Ok(dir) => format!("{dir}/../BENCH_throughput.json"),
            Err(_) => "BENCH_throughput.json".to_string(),
        }
    });
    std::fs::write(&out, format!("{}\n", Json::Obj(top)))?;
    println!(
        "wrote {out} (matmul speedup min {matmul_min_speedup:.2}x / \
         best overall {best_speedup:.2}x on {cores} cores)"
    );

    if let Ok(raw) = std::env::var("COLA_BENCH_MIN_SPEEDUP") {
        // a malformed threshold must not silently disable the gate
        let minv: f64 = match raw.parse() {
            Ok(v) => v,
            Err(_) => {
                eprintln!("COLA_BENCH_MIN_SPEEDUP={raw:?} is not a number");
                std::process::exit(1);
            }
        };
        if matmul_min_speedup < minv {
            eprintln!(
                "PERF REGRESSION: worst-case matmul speedup \
                 {matmul_min_speedup:.2}x < required {minv:.2}x ({cores} cores)"
            );
            std::process::exit(1);
        }
    }
    Ok(())
}
