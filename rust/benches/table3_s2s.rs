//! Table 3: sequence-to-sequence (BART/S2S-suite substitute) — 6
//! synthetic transform tasks x the method grid, teacher-forced token
//! accuracy x100 as the ROUGE-Longest stand-in. Curves -> Figs 15-16 CSV.

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::Task;
use cola::data::lm::S2S_TASKS;
use cola::metrics::{curves_to_csv, markdown_table, Curve};

fn main() -> anyhow::Result<()> {
    let (steps, quick) = common::bench_args();
    let grid = if quick { common::quick_grid() } else { common::method_grid() };
    let tasks: &[&str] = if quick { &S2S_TASKS[..2] } else { &S2S_TASKS };

    let mut report = BenchReport::new(&format!(
        "Table 3 — seq2seq, {} tasks x {} methods, {} steps",
        tasks.len(), grid.len(), steps));
    let mut rows = Vec::new();
    let mut curves: Vec<Curve> = Vec::new();

    for (label, method, mode) in &grid {
        let mut row = vec![label.clone(), String::new()];
        let mut scores = Vec::new();
        for task in tasks {
            let mut cfg = common::base_quality_cfg(Task::S2s, task, steps);
            cfg.eval_every = (steps / 6).max(1);
            let r = common::run_arm(cfg, *method, *mode)?;
            let score = r.score();
            scores.push(score);
            row.push(format!("{score:.1}"));
            row[1] = common::fmt_params(r.trainable_params);
            let mut c = r.eval_acc.clone();
            c.name = format!("{label}/{task}");
            curves.push(c);
        }
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        row.push(format!("{avg:.1}"));
        println!("{label:32} avg {avg:.1}");
        rows.push(row);
    }

    let mut headers: Vec<&str> = vec!["Method", "Trainable"];
    headers.extend(tasks.iter().copied());
    headers.push("Avg.");
    report.section("token accuracy x100 (ROUGE-Longest stand-in)",
                   markdown_table(&headers, &rows));
    report.emit("table3_s2s")?;
    let refs: Vec<&Curve> = curves.iter().collect();
    report.write_csv("fig15_16_s2s_curves", &curves_to_csv(&refs))?;
    Ok(())
}
