//! Million-user scale baseline (EXPERIMENTS.md §Scale harness).
//!
//! Drives the `cola::scale` harness twice — unbounded (paging off) and
//! with a bounded LRU working set paging cold adapter state to disk —
//! and emits the machine-readable baseline to `BENCH_scale.json`
//! (override with `COLA_BENCH_SCALE_OUT`). Headline figures are the
//! paged arm's: users/sec, p99 interval latency, resident bytes, and
//! page faults per interval. The bench also byte-compares the two
//! arms' curves: paging must never move a number, at any working-set
//! size — a divergence here is a correctness bug, not a perf note.

#[path = "common.rs"]
mod common;

use std::collections::BTreeMap;
use std::time::Instant;

use cola::bench_harness::BenchReport;
use cola::metrics::markdown_table;
use cola::scale::{ScaleCfg, ScaleHarness, ScaleSummary};
use cola::util::json::Json;

struct ArmResult {
    summary: ScaleSummary,
    curve_hex: String,
    users_per_sec: f64,
    p99_interval_ms: f64,
    wall_s: f64,
}

fn run_arm(cfg: ScaleCfg) -> anyhow::Result<ArmResult> {
    let intervals = cfg.intervals;
    let mut harness = ScaleHarness::new(cfg)?;
    let t0 = Instant::now();
    let mut interval_secs = Vec::with_capacity(intervals);
    for _ in 0..intervals {
        let s = Instant::now();
        harness.run_interval()?;
        interval_secs.push(s.elapsed().as_secs_f64());
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let summary = harness.summary();
    anyhow::ensure!(summary.fits_lost == 0, "lost {} fits", summary.fits_lost);
    interval_secs.sort_by(|a, b| a.total_cmp(b));
    let p99 = interval_secs[((interval_secs.len() as f64 * 0.99).ceil() as usize)
        .saturating_sub(1)
        .min(interval_secs.len() - 1)];
    Ok(ArmResult {
        summary,
        curve_hex: harness.curve_hex(),
        users_per_sec: summary.fits_ok as f64 / wall_s,
        p99_interval_ms: p99 * 1e3,
        wall_s,
    })
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() -> anyhow::Result<()> {
    let (_steps, quick) = common::bench_args();
    // quick = the bench-smoke CI shape; full = the 10^5-user baseline
    let (users, intervals, touches, workers, working_set) = if quick {
        (2_000, 8, 256, 4, 64)
    } else {
        (100_000, 20, 2_048, 8, 256)
    };
    let page_dir = std::env::temp_dir()
        .join(format!("cola_bench_scale_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&page_dir);
    let base = ScaleCfg {
        users,
        intervals,
        touches_per_interval: touches,
        workers,
        working_set: 0,
        page_dir: None,
        seed: 0xC01A,
        rows: 4,
    };

    let unpaged = run_arm(base.clone())?;
    let paged = run_arm(ScaleCfg {
        working_set,
        page_dir: Some(page_dir.clone()),
        ..base
    })?;
    let _ = std::fs::remove_dir_all(&page_dir);

    // the determinism half of the bench: paging on/off is invisible in
    // the numbers, byte for byte
    anyhow::ensure!(
        unpaged.curve_hex == paged.curve_hex,
        "paged and unpaged curves diverged — paging moved a number"
    );
    anyhow::ensure!(
        paged.summary.page_stats.faults > 0,
        "the paged arm never faulted — working_set {working_set} is not \
         exercising the pager at these sizes"
    );
    anyhow::ensure!(paged.summary.page_stats.page_errors == 0, "page errors");
    anyhow::ensure!(
        paged.summary.resident_bytes < unpaged.summary.resident_bytes,
        "bounded working set did not reduce resident bytes"
    );

    let mut report = BenchReport::new("Scale harness: LRU adapter-state paging");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let ws_label = format!("ws={working_set}");
    for (label, arm) in [("unpaged", &unpaged), (ws_label.as_str(), &paged)] {
        rows.push(vec![
            label.to_string(),
            format!("{:.0}", arm.users_per_sec),
            format!("{:.1}", arm.p99_interval_ms),
            format!("{:.1}", arm.summary.resident_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.1}", arm.summary.page_stats.faults as f64 / intervals as f64),
            format!("{:.2}", arm.wall_s),
        ]);
    }
    report.section(
        &format!(
            "{users} users, {intervals} intervals x {touches} touches, \
             {workers} workers (curves byte-identical across arms)"
        ),
        markdown_table(
            &["arm", "users/sec", "p99 interval ms", "resident MiB",
              "faults/interval", "wall s"],
            &rows,
        ),
    );
    report.emit("scale")?;

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("scale".to_string()));
    top.insert("schema".to_string(), num(1.0));
    top.insert("quick".to_string(), Json::Bool(quick));
    top.insert("users".to_string(), num(users as f64));
    top.insert("intervals".to_string(), num(intervals as f64));
    top.insert("touches_per_interval".to_string(), num(touches as f64));
    top.insert("workers".to_string(), num(workers as f64));
    top.insert("working_set".to_string(), num(working_set as f64));
    top.insert(
        "users_registered".to_string(),
        num(paged.summary.users_registered as f64),
    );
    // headline figures come from the paged arm — that is the
    // configuration the scale story ships
    top.insert("users_per_sec".to_string(), num(paged.users_per_sec));
    top.insert("p99_interval_ms".to_string(), num(paged.p99_interval_ms));
    top.insert("resident_bytes".to_string(), num(paged.summary.resident_bytes as f64));
    top.insert(
        "page_faults_per_interval".to_string(),
        num(paged.summary.page_stats.faults as f64 / intervals as f64),
    );
    top.insert(
        "page_evictions".to_string(),
        num(paged.summary.page_stats.evictions as f64),
    );
    top.insert(
        "unpaged_users_per_sec".to_string(),
        num(unpaged.users_per_sec),
    );
    top.insert(
        "unpaged_resident_bytes".to_string(),
        num(unpaged.summary.resident_bytes as f64),
    );
    top.insert("curves_byte_identical".to_string(), Json::Bool(true));
    let out = std::env::var("COLA_BENCH_SCALE_OUT").unwrap_or_else(|_| {
        match std::env::var("CARGO_MANIFEST_DIR") {
            Ok(dir) => format!("{dir}/../BENCH_scale.json"),
            Err(_) => "BENCH_scale.json".to_string(),
        }
    });
    std::fs::write(&out, format!("{}\n", Json::Obj(top)))?;
    println!(
        "wrote {out} ({:.0} users/sec paged vs {:.0} unpaged; resident \
         {:.1} MiB vs {:.1} MiB; curves byte-identical)",
        paged.users_per_sec,
        unpaged.users_per_sec,
        paged.summary.resident_bytes as f64 / (1024.0 * 1024.0),
        unpaged.summary.resident_bytes as f64 / (1024.0 * 1024.0),
    );
    Ok(())
}
