//! Table 9 + Figures 2-3: learning from scratch on the image models.
//! Linear/MLP/CNN x {FT, LoRA, ColA LowRank u/m, ColA Linear u/m,
//! ColA MLP} x {smnist, scifar}; accuracy + trainable params, learning
//! curves to CSV.

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::{AdapterKind, Method, Mode, Optimizer, TrainConfig};
use cola::coordinator::{Driver, Trainer};
use cola::metrics::{curves_to_csv, markdown_table, Curve};

fn run(model: &str, set: &str, method: Method, mode: Mode, steps: usize)
       -> anyhow::Result<(f64, usize, Curve)> {
    let rt = common::shared_runtime().clone();
    let driver = Driver::new_ic(model, set, 32, 7)?;
    let mut cfg = TrainConfig::default();
    cfg.method = method;
    cfg.mode = mode;
    cfg.steps = steps;
    cfg.batch = 32;
    cfg.lr = 0.05;
    cfg.optimizer = Optimizer::Sgd;
    cfg.eval_every = (steps / 8).max(1);
    cfg.eval_batches = 6;
    let mut t = Trainer::with_driver(cfg, rt, driver)?;
    let r = t.run()?;
    Ok((100.0 * r.eval_acc.tail_mean(2), r.trainable_params, r.eval_acc))
}

fn main() -> anyhow::Result<()> {
    let (steps, quick) = common::bench_args();
    let models: &[&str] = if quick { &["mlp"] } else { &["linear", "mlp", "cnn"] };
    let sets: &[&str] = if quick { &["smnist"] } else { &["smnist", "scifar"] };
    let arms: Vec<(&str, Method, Mode)> = vec![
        ("FT", Method::Ft, Mode::Unmerged),
        ("LoRA", Method::Lora, Mode::Unmerged),
        ("ColA (Low Rank) unmerged", Method::Cola(AdapterKind::LowRank), Mode::Unmerged),
        ("ColA (Low Rank) merged", Method::Cola(AdapterKind::LowRank), Mode::Merged),
        ("ColA (Linear) unmerged", Method::Cola(AdapterKind::Linear), Mode::Unmerged),
        ("ColA (Linear) merged", Method::Cola(AdapterKind::Linear), Mode::Merged),
        ("ColA (MLP) unmerged", Method::Cola(AdapterKind::Mlp), Mode::Unmerged),
    ];

    let mut report = BenchReport::new(&format!(
        "Table 9 / Figs 2-3 — learning from scratch, {steps} steps"));
    let mut curves: Vec<Curve> = Vec::new();

    for model in models {
        let mut rows = Vec::new();
        for (label, method, mode) in &arms {
            let mut row = vec![label.to_string(), String::new()];
            for set in sets {
                let (acc, params, mut curve) = run(model, set, *method, *mode, steps)?;
                row[1] = common::fmt_params(params);
                row.push(format!("{acc:.1}"));
                curve.name = format!("{model}/{set}/{label}");
                curves.push(curve);
                println!("[{model:6}] {label:28} {set:7} {acc:5.1}");
            }
            rows.push(row);
        }
        let mut headers = vec!["Method", "Trainable"];
        headers.extend(sets.iter().copied());
        report.section(&format!("model = {model}"),
                       markdown_table(&headers, &rows));
    }

    report.emit("table9_scratch")?;
    let refs: Vec<&Curve> = curves.iter().collect();
    report.write_csv("fig2_3_scratch_curves", &curves_to_csv(&refs))?;
    Ok(())
}
