//! Table 2: sequence classification (GLUE substitute) — 8 synthetic
//! tasks x the full method grid, reporting end-of-training accuracy
//! (x100, the GLUE-metric stand-in) and trainable parameters. Learning
//! curves (Figs 12-14) are emitted as CSV.
//!
//!   cargo bench --bench table2_seqcls            full grid
//!   cargo bench --bench table2_seqcls -- --quick reduced grid
//!   ... -- --steps N                             override steps

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::Task;
use cola::data::seqcls::TASKS;
use cola::metrics::{curves_to_csv, markdown_table, Curve};

fn main() -> anyhow::Result<()> {
    let (steps, quick) = common::bench_args();
    let grid = if quick { common::quick_grid() } else { common::method_grid() };
    let tasks: &[&str] = if quick { &TASKS[..2] } else { &TASKS };

    let mut report = BenchReport::new(&format!(
        "Table 2 — seq classification, {} tasks x {} methods, {} steps",
        tasks.len(), grid.len(), steps));
    let mut rows = Vec::new();
    let mut curves: Vec<Curve> = Vec::new();

    for (label, method, mode) in &grid {
        let mut row = vec![label.clone(), String::new()];
        let mut scores = Vec::new();
        for task in tasks {
            let cfg = common::base_quality_cfg(Task::SeqCls, task, steps);
            let mut cfg = cfg;
            cfg.eval_every = (steps / 6).max(1);
            let r = common::run_arm(cfg, *method, *mode)?;
            let score = r.score();
            scores.push(score);
            row.push(format!("{score:.1}"));
            row[1] = common::fmt_params(r.trainable_params);
            let mut c = r.eval_acc.clone();
            c.name = format!("{label}/{task}");
            curves.push(c);
        }
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        row.push(format!("{avg:.1}"));
        println!("{label:32} avg {avg:.1}");
        rows.push(row);
    }

    let mut headers: Vec<&str> = vec!["Method", "Trainable"];
    headers.extend(tasks.iter().copied());
    headers.push("Avg.");
    report.section("accuracy x100 (GLUE-metric stand-in)",
                   markdown_table(&headers, &rows));
    report.emit("table2_seqcls")?;
    let refs: Vec<&Curve> = curves.iter().collect();
    report.write_csv("fig12_14_seqcls_curves", &curves_to_csv(&refs))?;
    Ok(())
}
