//! Figures 4-11: adaptation-interval ablation. For I in {1, 2, 4, 8}
//! train with the same number of server iterations (so I=8 updates the
//! adapters 8x less often), on seq-cls, CLM, and IC tasks; emit eval
//! curves as CSV and the end scores as a table. The paper's finding:
//! larger I ~ larger effective batch, satisfactory convergence with
//! fewer (and cheaper, amortized) adapter updates.

#[path = "common.rs"]
mod common;

use cola::bench_harness::BenchReport;
use cola::config::{AdapterKind, Method, Mode, Optimizer, Task, TrainConfig};
use cola::coordinator::{Driver, Trainer};
use cola::metrics::{curves_to_csv, markdown_table, Curve};

const INTERVALS: [usize; 4] = [1, 2, 4, 8];

fn main() -> anyhow::Result<()> {
    let (steps, quick) = common::bench_args();
    let mut report = BenchReport::new(&format!(
        "Figs 4-11 — adaptation interval ablation, {steps} steps"));
    let mut curves: Vec<Curve> = Vec::new();

    // seq-cls (Figs 4-6) + CLM (Fig 9)
    let lm_arms: Vec<(&str, Task, &str)> = if quick {
        vec![("sst2", Task::SeqCls, "sst2")]
    } else {
        vec![("sst2", Task::SeqCls, "sst2"),
             ("mnli", Task::SeqCls, "mnli"),
             ("fpb", Task::S2s, "fpb"),
             ("dolly", Task::Clm, "dolly")]
    };
    for (name, task, dataset) in lm_arms {
        let mut rows = Vec::new();
        for &interval in &INTERVALS {
            let mut cfg = common::base_quality_cfg(task, dataset, steps);
            cfg.method = Method::Cola(AdapterKind::LowRank);
            cfg.mode = Mode::Unmerged; // matches the paper's ablation setup
            cfg.interval = interval;
            cfg.eval_every = (steps / 8).max(1);
            let mut t = Trainer::new(cfg)?;
            let r = t.run()?;
            let score = r.score();
            println!("[{name:6}] I={interval}  score {score:.1}");
            rows.push(vec![format!("{interval}"), format!("{score:.1}"),
                           format!("{}", steps / interval)]);
            let mut c = r.eval_acc.clone();
            c.name = format!("{name}/I{interval}");
            curves.push(c);
        }
        report.section(&format!("{name}: score vs adaptation interval"),
                       markdown_table(&["I", "score", "adapter updates"], &rows));
    }

    // IC (Figs 10-11)
    if !quick {
        let mut rows = Vec::new();
        for &interval in &INTERVALS {
            let rt = common::shared_runtime().clone();
            let driver = Driver::new_ic("mlp", "smnist", 32, 7)?;
            let mut cfg = TrainConfig::default();
            cfg.method = Method::Cola(AdapterKind::Linear);
            cfg.mode = Mode::Unmerged;
            cfg.steps = steps;
            cfg.batch = 32;
            cfg.lr = 0.05;
            cfg.optimizer = Optimizer::Sgd;
            cfg.interval = interval;
            cfg.eval_every = (steps / 8).max(1);
            cfg.eval_batches = 6;
            let mut t = Trainer::with_driver(cfg, rt, driver)?;
            let r = t.run()?;
            let acc = 100.0 * r.eval_acc.tail_mean(2);
            println!("[ic-mlp] I={interval}  acc {acc:.1}");
            rows.push(vec![format!("{interval}"), format!("{acc:.1}")]);
            let mut c = r.eval_acc.clone();
            c.name = format!("ic-mlp/I{interval}");
            curves.push(c);
        }
        report.section("ic-mlp (smnist): accuracy vs interval",
                       markdown_table(&["I", "acc"], &rows));
    }

    report.emit("fig_interval")?;
    let refs: Vec<&Curve> = curves.iter().collect();
    report.write_csv("fig4_11_interval_curves", &curves_to_csv(&refs))?;
    Ok(())
}
