"""custom_vjp wrappers: gradients through Pallas kernels match autodiff
through the pure-jnp references (the server graph depends on these)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, vjp

jax.config.update("jax_platform_name", "cpu")


def arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 64, 130]), d=st.sampled_from([8, 32]),
       r=st.sampled_from([2, 8]), seed=st.integers(0, 2**16))
def test_lora_apply_vjp(n, d, r, seed):
    rng = np.random.default_rng(seed)
    x, a, b, h = arr(rng, n, d), arr(rng, d, r), arr(rng, r, d), arr(rng, n, d)
    f1 = lambda *args: jnp.sum(jnp.sin(vjp.lora_apply(*args, 0.5)))
    f2 = lambda *args: jnp.sum(jnp.sin(ref.lora_apply_ref(*args, 0.5)))
    g1 = jax.grad(f1, argnums=(0, 1, 2, 3))(x, a, b, h)
    g2 = jax.grad(f2, argnums=(0, 1, 2, 3))(x, a, b, h)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=4e-4, atol=4e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 64]), d=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**16))
def test_linear_apply_vjp(n, d, seed):
    rng = np.random.default_rng(seed)
    x, w, h = arr(rng, n, d), arr(rng, d, d), arr(rng, n, d)
    f1 = lambda *args: jnp.sum(jnp.sin(vjp.linear_apply(*args, 1.0)))
    f2 = lambda *args: jnp.sum(jnp.sin(ref.linear_apply_ref(*args, 1.0)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(x, w, h)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(x, w, h)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=4e-4, atol=4e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 64]), dh=st.sampled_from([4, 16]),
       causal=st.booleans(), seed=st.integers(0, 2**16))
def test_attention_vjp(s, dh, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = arr(rng, s, dh), arr(rng, s, dh), arr(rng, s, dh)
    f1 = lambda *args: jnp.sum(jnp.cos(vjp.attention(*args, causal)))
    f2 = lambda *args: jnp.sum(jnp.cos(ref.attention_ref(*args, causal)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for u, v_ in zip(g1, g2):
        np.testing.assert_allclose(u, v_, rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 100]), d=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**16))
def test_layernorm_vjp(n, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = arr(rng, n, d), arr(rng, d), arr(rng, d)
    f1 = lambda *args: jnp.sum(jnp.sin(vjp.layernorm(*args)))
    f2 = lambda *args: jnp.sum(jnp.sin(ref.layernorm_ref(*args)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(x, g, b)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(x, g, b)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=5e-4, atol=5e-4)
