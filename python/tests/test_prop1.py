"""Proposition 1 — the heart of ColA.

The decoupled path (server ships (x_m, grad_hhat_m); worker computes the
surrogate-loss gradient) must produce EXACTLY the coupled autodiff
gradients of the task loss w.r.t. the adapter parameters, for every
adapter architecture and site. These tests verify it at the JAX level on
the same graphs that get AOT-lowered.
"""
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from compile import adapter_update, baselines, ic_models, model

jax.config.update("jax_platform_name", "cpu")

CFG = dict(model.CONFIGS["tiny"], batch=4, seq=32)
RTOL, ATOL = 2e-4, 2e-4


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, CFG["vocab"], (CFG["batch"], CFG["seq"]))
                         .astype(np.int32))
    targets = jnp.asarray(rng.integers(0, CFG["vocab"], (CFG["batch"], CFG["seq"]))
                          .astype(np.int32))
    mask = jnp.asarray((rng.random((CFG["batch"], CFG["seq"])) > 0.2)
                       .astype(np.float32))
    return tokens, targets, mask


def _perturbed_lowrank(cfg, seed=7):
    """Adapters with non-zero B so gradients flow through both factors."""
    aps = model.init_adapter_params(cfg, "lowrank")
    rng = np.random.default_rng(seed)
    out = OrderedDict()
    for k, v in aps.items():
        out[k] = jnp.asarray(rng.normal(scale=0.05, size=v.shape).astype(np.float32))
    return out


def test_prop1_lowrank_clm():
    """Decoupled fit grads == coupled LoRA grads, every site, exact."""
    params = model.init_lm_params(CFG)
    aps = _perturbed_lowrank(CFG)
    tokens, targets, mask = _batch()

    fwdbwd, in_names, out_names, _ = model.make_lm_fwdbwd(CFG, "lowrank")
    args = list(params.values()) + list(aps.values()) + [tokens, targets, mask]
    outs = dict(zip(out_names, fwdbwd(*args)))

    coupled, cin, conames, _ = baselines.make_coupled_clm_step(CFG, "lora")
    couts = dict(zip(conames, coupled(*args)))

    np.testing.assert_allclose(outs["loss"], couts["loss"], rtol=1e-5)

    d = CFG["d"]
    for i in range(CFG["layers"]):
        x = outs[f"l{i}.x"].reshape(-1, d)
        for proj, gkey in (("q", f"l{i}.gq"), ("v", f"l{i}.gv")):
            ghat = outs[gkey].reshape(-1, d)
            fit, _, _, _ = adapter_update.make_fit_grad("lowrank", d, d,
                                                        x.shape[0])
            da, db = fit(x, ghat, aps[f"l{i}.{proj}.A"], aps[f"l{i}.{proj}.B"])
            np.testing.assert_allclose(da, couts[f"d.l{i}.{proj}.A"],
                                       rtol=RTOL, atol=ATOL)
            np.testing.assert_allclose(db, couts[f"d.l{i}.{proj}.B"],
                                       rtol=RTOL, atol=ATOL)


def test_prop1_linear_and_mlp_clm():
    """Prop.1 holds for any auxiliary architecture (model-agnostic)."""
    params = model.init_lm_params(CFG)
    tokens, targets, mask = _batch(1)
    d = CFG["d"]
    for kind in ("linear", "mlp"):
        aps = model.init_adapter_params(CFG, kind)
        rng = np.random.default_rng(11)
        aps = OrderedDict(
            (k, jnp.asarray(rng.normal(scale=0.02, size=v.shape).astype(np.float32)))
            for k, v in aps.items())
        fwdbwd, _, out_names, _ = model.make_lm_fwdbwd(CFG, kind)
        args = list(params.values()) + list(aps.values()) + [tokens, targets, mask]
        outs = dict(zip(out_names, fwdbwd(*args)))

        # coupled oracle via direct autodiff on the same forward
        def loss_fn(aps_d):
            hidden, _ = model.lm_forward(params, tokens, CFG, kind=kind,
                                         adapters=aps_d, use_pallas=True)
            return model.masked_ce(model.lm_logits(params, hidden), targets, mask)

        grads = jax.grad(loss_fn)(aps)

        for i in range(CFG["layers"]):
            x = outs[f"l{i}.x"].reshape(-1, d)
            for proj, gkey in (("q", f"l{i}.gq"), ("v", f"l{i}.gv")):
                ghat = outs[gkey].reshape(-1, d)
                fit, _, onames, _ = adapter_update.make_fit_grad(
                    kind, d, d, x.shape[0])
                p = f"l{i}.{proj}"
                if kind == "linear":
                    (dw,) = fit(x, ghat, aps[f"{p}.W"])
                    np.testing.assert_allclose(dw, grads[f"{p}.W"],
                                               rtol=RTOL, atol=ATOL)
                else:
                    douts = fit(x, ghat, aps[f"{p}.W1"], aps[f"{p}.b1"],
                                aps[f"{p}.W2"], aps[f"{p}.b2"])
                    for got, name in zip(douts, ("W1", "b1", "W2", "b2")):
                        np.testing.assert_allclose(
                            got, grads[f"{p}.{name}"], rtol=4e-4, atol=4e-4)


def test_prop1_seqcls_head():
    """The classifier head trained through a 'linear' ColA adapter gets
    exactly the coupled head gradient."""
    n_classes = 4
    params = model.init_lm_params(CFG)
    aps = _perturbed_lowrank(CFG)
    rng = np.random.default_rng(3)
    head_w = jnp.asarray(rng.normal(scale=0.05,
                                    size=(CFG["d"], n_classes)).astype(np.float32))
    tokens, _, mask = _batch(2)
    labels = jnp.asarray(rng.integers(0, n_classes, (CFG["batch"],)).astype(np.int32))

    fwdbwd, _, out_names, _ = model.make_seqcls_fwdbwd(CFG, "lowrank", n_classes)
    args = (list(params.values()) + list(aps.values())
            + [head_w, tokens, labels, mask])
    outs = dict(zip(out_names, fwdbwd(*args)))

    def loss_fn(hw):
        hidden, _ = model.lm_forward(params, tokens, CFG, kind="lowrank",
                                     adapters=aps, causal=False, use_pallas=True)
        _, logits = model.seqcls_logits(hidden, mask, hw)
        return model.ce_labels(logits, labels)

    ghead_ref = jax.grad(loss_fn)(head_w)

    fit, _, _, _ = adapter_update.make_fit_grad("linear", CFG["d"], n_classes,
                                                CFG["batch"])
    (dw,) = fit(outs["head.x"], outs["head.g"], head_w)
    np.testing.assert_allclose(dw, ghead_ref, rtol=RTOL, atol=ATOL)


def test_prop1_one_sgd_step_identical():
    """A full GL round (fit grads -> SGD) lands on the same adapter
    weights as a coupled LoRA SGD step: ColA(LowRank) == LoRA exactly."""
    params = model.init_lm_params(CFG)
    aps = _perturbed_lowrank(CFG)
    tokens, targets, mask = _batch(4)
    lr = 0.1
    d = CFG["d"]

    fwdbwd, _, out_names, _ = model.make_lm_fwdbwd(CFG, "lowrank")
    args = list(params.values()) + list(aps.values()) + [tokens, targets, mask]
    outs = dict(zip(out_names, fwdbwd(*args)))

    coupled, _, conames, _ = baselines.make_coupled_clm_step(CFG, "lora")
    couts = dict(zip(conames, coupled(*args)))

    for i in range(CFG["layers"]):
        x = outs[f"l{i}.x"].reshape(-1, d)
        for proj, gkey in (("q", f"l{i}.gq"), ("v", f"l{i}.gv")):
            ghat = outs[gkey].reshape(-1, d)
            fit, _, _, _ = adapter_update.make_fit_grad("lowrank", d, d, x.shape[0])
            p = f"l{i}.{proj}"
            da, db = fit(x, ghat, aps[f"{p}.A"], aps[f"{p}.B"])
            a_gl = aps[f"{p}.A"] - lr * da
            a_cp = aps[f"{p}.A"] - lr * couts[f"d.{p}.A"]
            np.testing.assert_allclose(a_gl, a_cp, rtol=RTOL, atol=ATOL)
            b_gl = aps[f"{p}.B"] - lr * db
            b_cp = aps[f"{p}.B"] - lr * couts[f"d.{p}.B"]
            np.testing.assert_allclose(b_gl, b_cp, rtol=RTOL, atol=ATOL)


def test_prop1_ic_models():
    """Prop.1 on the image models (from-scratch study), incl. conv sites
    via im2col."""
    batch = 8
    rng = np.random.default_rng(5)
    images = jnp.asarray(rng.normal(size=(batch, ic_models.IMG, ic_models.IMG, 1))
                         .astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, (batch,)).astype(np.int32))
    for m in ("linear", "mlp", "cnn"):
        base = ic_models.init_ic_base(m)
        aps = ic_models.init_ic_adapters(m, "lowrank")
        aps = OrderedDict(
            (k, jnp.asarray(rng.normal(scale=0.05, size=v.shape).astype(np.float32)))
            for k, v in aps.items())
        fwdbwd, _, onames, _ = ic_models.make_ic_fwdbwd(m, "lowrank", batch)
        outs = dict(zip(onames, fwdbwd(*base.values(), *aps.values(),
                                       images, labels)))

        coupled, _, cnames, _ = ic_models.make_ic_coupled(m, "lora", batch)
        couts = dict(zip(cnames, coupled(*base.values(), *aps.values(),
                                         images, labels)))
        np.testing.assert_allclose(outs["loss"], couts["loss"], rtol=1e-5)

        for site, (din, dout, rows) in ic_models.ic_site_dims(m).items():
            fit, _, _, _ = adapter_update.make_fit_grad(
                "lowrank", din, dout, batch * rows)
            # adjust rank for narrow sites
            da, db = fit(outs[f"{site}.x"], outs[f"{site}.g"],
                         aps[f"{site}.A"], aps[f"{site}.B"])
            np.testing.assert_allclose(da, couts[f"d.{site}.A"],
                                       rtol=4e-4, atol=4e-4)
            np.testing.assert_allclose(db, couts[f"d.{site}.B"],
                                       rtol=4e-4, atol=4e-4)


def test_interval_buffering_sums_per_batch_grads():
    """Fitting on the concatenation of I buffered batches equals the sum
    of per-batch fit gradients (SUM-reduction surrogate) — the invariant
    the Rust buffer relies on."""
    rng = np.random.default_rng(9)
    d, n = 16, 64
    a = jnp.asarray(rng.normal(size=(d, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    xs = [jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)) for _ in range(3)]
    gs = [jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)) for _ in range(3)]
    fit_n, _, _, _ = adapter_update.make_fit_grad("lowrank", d, d, n)
    fit_3n, _, _, _ = adapter_update.make_fit_grad("lowrank", d, d, 3 * n)
    da_cat, db_cat = fit_3n(jnp.concatenate(xs), jnp.concatenate(gs), a, b)
    da_sum = sum(fit_n(x, g, a, b)[0] for x, g in zip(xs, gs))
    db_sum = sum(fit_n(x, g, a, b)[1] for x, g in zip(xs, gs))
    np.testing.assert_allclose(da_cat, da_sum, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(db_cat, db_sum, rtol=2e-4, atol=2e-4)
