"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes; every kernel must match its ref across row
counts that do and don't divide the block size, multiple block sizes,
and non-trivial scales.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as katt
from compile.kernels import fit_step as kfit
from compile.kernels import lora as klora
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


dims = st.sampled_from([1, 3, 8, 16, 32, 64])
rows = st.sampled_from([1, 5, 8, 64, 100, 128, 200])
blocks = st.sampled_from([16, 64, 128])
scales = st.sampled_from([1.0, 0.5, 2.0])


@settings(max_examples=25, deadline=None)
@given(n=rows, d_in=dims, r=st.sampled_from([1, 4, 8]), d_out=dims,
       bn=blocks, s=scales, seed=st.integers(0, 2**16))
def test_lora_apply_matches_ref(n, d_in, r, d_out, bn, s, seed):
    rng = np.random.default_rng(seed)
    x, a, b, h = arr(rng, n, d_in), arr(rng, d_in, r), arr(rng, r, d_out), arr(rng, n, d_out)
    got = klora.lora_apply(x, a, b, h, s, block_n=bn)
    want = ref.lora_apply_ref(x, a, b, h, s)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(n=rows, d_in=dims, d_out=dims, bn=blocks, s=scales,
       seed=st.integers(0, 2**16))
def test_linear_apply_matches_ref(n, d_in, d_out, bn, s, seed):
    rng = np.random.default_rng(seed)
    x, w, h = arr(rng, n, d_in), arr(rng, d_in, d_out), arr(rng, n, d_out)
    got = klora.linear_apply(x, w, h, s, block_n=bn)
    np.testing.assert_allclose(got, ref.linear_apply_ref(x, w, h, s),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(n=rows, d_in=dims, r=st.sampled_from([1, 4, 8]), d_out=dims,
       bn=blocks, s=scales, seed=st.integers(0, 2**16))
def test_fit_lowrank_matches_ref(n, d_in, r, d_out, bn, s, seed):
    rng = np.random.default_rng(seed)
    x, t = arr(rng, n, d_in), arr(rng, n, d_out)
    a, b = arr(rng, d_in, r), arr(rng, r, d_out)
    da, db = kfit.fit_step_lowrank(x, t, a, b, s, block_n=bn)
    rda, rdb = ref.fit_step_lowrank_ref(x, t, a, b, s)
    np.testing.assert_allclose(da, rda, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(db, rdb, rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(n=rows, d_in=dims, d_out=dims, bn=blocks, s=scales,
       seed=st.integers(0, 2**16))
def test_fit_linear_matches_ref(n, d_in, d_out, bn, s, seed):
    rng = np.random.default_rng(seed)
    x, t, w = arr(rng, n, d_in), arr(rng, n, d_out), arr(rng, d_in, d_out)
    got = kfit.fit_step_linear(x, t, w, s, block_n=bn)
    np.testing.assert_allclose(got, ref.fit_step_linear_ref(x, t, w, s),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=rows, d_in=dims, dh=st.sampled_from([4, 16]), d_out=dims,
       bn=blocks, seed=st.integers(0, 2**16))
def test_fit_mlp_matches_ref(n, d_in, dh, d_out, bn, seed):
    rng = np.random.default_rng(seed)
    x, t = arr(rng, n, d_in), arr(rng, n, d_out)
    w1, b1 = arr(rng, d_in, dh), arr(rng, dh)
    w2, b2 = arr(rng, dh, d_out), arr(rng, d_out)
    got = kfit.fit_step_mlp(x, t, w1, b1, w2, b2, block_n=bn)
    want = ref.fit_step_mlp_ref(x, t, w1, b1, w2, b2)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=4e-4, atol=4e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([16, 64, 128]), dh=st.sampled_from([4, 16, 32]),
       bq=st.sampled_from([8, 16, 64]), causal=st.booleans(),
       seed=st.integers(0, 2**16))
def test_attention_matches_ref(s, dh, bq, causal, seed):
    if s % min(bq, s) != 0:
        return
    rng = np.random.default_rng(seed)
    q, k, v = arr(rng, s, dh), arr(rng, s, dh), arr(rng, s, dh)
    got = katt.attention(q, k, v, causal, block_q=bq)
    np.testing.assert_allclose(got, ref.attention_ref(q, k, v, causal),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(n=rows, d=dims, seed=st.integers(0, 2**16))
def test_layernorm_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = arr(rng, n, d), arr(rng, d), arr(rng, d)
    got = katt.layernorm(x, g, b)
    np.testing.assert_allclose(got, ref.layernorm_ref(x, g, b),
                               rtol=1e-4, atol=1e-4)


def test_attention_rejects_bad_seq():
    q = jnp.ones((100, 8))
    with pytest.raises(ValueError):
        katt.attention(q, q, q, True, block_q=64)


def test_fit_lowrank_zero_rows_padding_neutral():
    """Explicitly: zero-padded rows contribute zero gradient."""
    rng = np.random.default_rng(0)
    x, t = arr(rng, 7, 8), arr(rng, 7, 8)
    a, b = arr(rng, 8, 4), arr(rng, 4, 8)
    da1, db1 = kfit.fit_step_lowrank(x, t, a, b, 1.0, block_n=128)
    da2, db2 = ref.fit_step_lowrank_ref(x, t, a, b, 1.0)
    np.testing.assert_allclose(da1, da2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(db1, db2, rtol=1e-4, atol=1e-4)
