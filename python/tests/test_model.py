"""L2 model graphs: merge correctness (Prop. 2), shapes, baselines."""
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from compile import baselines, ic_models, model

jax.config.update("jax_platform_name", "cpu")

CFG = dict(model.CONFIGS["tiny"], batch=4, seq=32)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, CFG["vocab"], (CFG["batch"], CFG["seq"]))
                         .astype(np.int32))
    targets = jnp.asarray(rng.integers(0, CFG["vocab"], (CFG["batch"], CFG["seq"]))
                          .astype(np.int32))
    mask = jnp.ones((CFG["batch"], CFG["seq"]), jnp.float32)
    return tokens, targets, mask


def _rand_adapters(kind, scale=0.05, seed=7):
    aps = model.init_adapter_params(CFG, kind)
    rng = np.random.default_rng(seed)
    return OrderedDict(
        (k, jnp.asarray(rng.normal(scale=scale, size=v.shape).astype(np.float32)))
        for k, v in aps.items())


def _merge_lowrank(params, aps):
    """Prop. 2: wq' = wq + scale * A@B (adapter linear in input)."""
    out = OrderedDict(params)
    for i in range(CFG["layers"]):
        for proj, wname in (("q", f"l{i}.wq"), ("v", f"l{i}.wv")):
            p = f"l{i}.{proj}"
            out[wname] = params[wname] + model.ADAPTER_SCALE * (
                aps[f"{p}.A"] @ aps[f"{p}.B"])
    return out


def test_merged_equals_unmerged_lowrank():
    """Forward+backward through merged weights == live lowrank adapters:
    same loss, same x_m, same grad_hhat_m."""
    params = model.init_lm_params(CFG)
    aps = _rand_adapters("lowrank")
    tokens, targets, mask = _batch()

    un, _, onames, _ = model.make_lm_fwdbwd(CFG, "lowrank")
    args_un = list(params.values()) + list(aps.values()) + [tokens, targets, mask]
    outs_un = dict(zip(onames, un(*args_un)))

    merged = _merge_lowrank(params, aps)
    mg, _, monames, _ = model.make_lm_fwdbwd(CFG, "none")
    args_m = list(merged.values()) + [tokens, targets, mask]
    outs_m = dict(zip(monames, mg(*args_m)))

    np.testing.assert_allclose(outs_un["loss"], outs_m["loss"], rtol=1e-5, atol=1e-6)
    for i in range(CFG["layers"]):
        np.testing.assert_allclose(outs_un[f"l{i}.x"], outs_m[f"l{i}.x"],
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(outs_un[f"l{i}.gq"], outs_m[f"l{i}.gq"],
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(outs_un[f"l{i}.gv"], outs_m[f"l{i}.gv"],
                                   rtol=2e-4, atol=2e-4)


def test_merge_unmerge_roundtrip():
    params = model.init_lm_params(CFG)
    aps = _rand_adapters("lowrank")
    merged = _merge_lowrank(params, aps)
    for i in range(CFG["layers"]):
        for proj, wname in (("q", f"l{i}.wq"), ("v", f"l{i}.wv")):
            p = f"l{i}.{proj}"
            back = merged[wname] - model.ADAPTER_SCALE * (aps[f"{p}.A"] @ aps[f"{p}.B"])
            np.testing.assert_allclose(back, params[wname], rtol=1e-5, atol=1e-6)


def test_multi_user_merge_composition():
    """Merging K users' adapters == adding all deltas (collaboration)."""
    params = model.init_lm_params(CFG)
    users = [_rand_adapters("lowrank", seed=s) for s in (1, 2, 3)]
    merged = OrderedDict(params)
    for aps in users:
        merged = _merge_lowrank(merged, aps)
    for i in range(CFG["layers"]):
        for proj, wname in (("q", f"l{i}.wq"), ("v", f"l{i}.wv")):
            p = f"l{i}.{proj}"
            total = sum(aps[f"{p}.A"] @ aps[f"{p}.B"] for aps in users)
            np.testing.assert_allclose(merged[wname], params[wname] + total,
                                       rtol=1e-5, atol=1e-6)


def test_lm_fwd_shapes_and_determinism():
    fwd, _, _, _ = model.make_lm_fwd(CFG)
    params = model.init_lm_params(CFG)
    tokens, _, _ = _batch()
    (logits,) = fwd(*params.values(), tokens)
    assert logits.shape == (CFG["batch"], CFG["seq"], CFG["vocab"])
    (logits2,) = fwd(*params.values(), tokens)
    np.testing.assert_array_equal(logits, logits2)


def test_all_baseline_graphs_run():
    params = model.init_lm_params(CFG)
    tokens, targets, mask = _batch(2)
    for meth in ("ft", "lora", "ia3", "prompt", "ptuning", "prefix"):
        tun = baselines.init_tunables(CFG, meth)
        if meth == "ft":
            tun = OrderedDict((k, v) for k, v in model.init_lm_params(CFG).items())
        step, _, onames, _ = baselines.make_coupled_clm_step(CFG, meth)
        # FT artifacts exclude the frozen weights (XLA would prune them)
        wargs = [] if meth == "ft" else list(params.values())
        outs = step(*wargs, *tun.values(), tokens, targets, mask)
        loss, acc = outs[0], outs[1]
        assert np.isfinite(loss), meth
        assert 0.0 <= float(acc) <= 1.0, meth
        assert len(outs) == 2 + len(tun), meth
        # gradients must be finite and at least one nonzero
        total = sum(float(jnp.sum(jnp.abs(g))) for g in outs[2:])
        assert np.isfinite(total) and total > 0, meth


def test_baseline_seqcls_graphs_run():
    params = model.init_lm_params(CFG)
    rng = np.random.default_rng(0)
    tokens, _, mask = _batch(3)
    labels = jnp.asarray(rng.integers(0, 4, (CFG["batch"],)).astype(np.int32))
    for meth in ("ft", "lora", "ia3", "prompt", "ptuning", "prefix"):
        tun = baselines.init_tunables(CFG, meth, n_classes=4)
        if meth == "ft":
            base = model.init_lm_params(CFG)
            tun = OrderedDict(base)
            tun["head.W"] = jnp.zeros((CFG["d"], 4), jnp.float32)
        step, _, onames, _ = baselines.make_coupled_seqcls_step(CFG, meth, 4)
        wargs = [] if meth == "ft" else list(params.values())
        outs = step(*wargs, *tun.values(), tokens, labels, mask)
        assert np.isfinite(outs[0]) and 0.0 <= float(outs[1]) <= 1.0, meth


def test_ic_merged_equals_adapter_forward():
    """IC: zero base + linear adapters == merged weights (Prop. 2)."""
    batch = 8
    rng = np.random.default_rng(1)
    images = jnp.asarray(rng.normal(size=(batch, 28, 28, 1)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, (batch,)).astype(np.int32))
    for m in ("linear", "mlp", "cnn"):
        base = ic_models.init_ic_base(m)
        aps = ic_models.init_ic_adapters(m, "linear")
        aps = OrderedDict(
            (k, jnp.asarray(rng.normal(scale=0.05, size=v.shape).astype(np.float32)))
            for k, v in aps.items())
        un, _, onames, _ = ic_models.make_ic_fwdbwd(m, "linear", batch)
        outs_u = dict(zip(onames, un(*base.values(), *aps.values(),
                                     images, labels)))
        ws = [base[f"{s}.Wbase"] + aps[f"{s}.W"]
              for s in ic_models.ic_site_dims(m)]
        mg, _, monames, _ = ic_models.make_ic_fwdbwd_merged(m, batch)
        outs_m = dict(zip(monames, mg(*ws, images, labels)))
        np.testing.assert_allclose(outs_u["loss"], outs_m["loss"],
                                   rtol=1e-5, atol=1e-6)
        for s in ic_models.ic_site_dims(m):
            np.testing.assert_allclose(outs_u[f"{s}.g"], outs_m[f"{s}.g"],
                                       rtol=2e-4, atol=2e-4)


def test_prompt_shifts_positions():
    """Prompt baseline: logits are cut back to seq positions, loss masked
    identically to no-prompt shape conventions."""
    params = model.init_lm_params(CFG)
    tun = baselines.init_tunables(CFG, "prompt")
    tokens, targets, mask = _batch(4)
    step, _, _, _ = baselines.make_coupled_clm_step(CFG, "prompt")
    outs = step(*params.values(), *tun.values(), tokens, targets, mask)
    assert np.isfinite(outs[0])
    assert outs[2].shape == tun["prompt"].shape
