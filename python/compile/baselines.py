"""L2: coupled-baseline step graphs (classic PEFT / FT autodiff).

These are the comparators of Tables 2/3/6/7: full fine-tuning (FT), LoRA,
IA3, Prompt Tuning, Prefix Tuning and P-Tuning, implemented as ordinary
coupled autodiff — the loss gradient w.r.t. the tunable parameters is
computed in the same backward pass as the hidden-representation
gradients (exactly what ColA decouples).

Each graph returns (loss[, acc], grads-of-tunables...); the optimizer
runs in the Rust coordinator (same optimizer implementation for every
method, so quality comparisons isolate the learning rule, and the
coupled LoRA graph doubles as the Prop.1 exactness oracle against the
decoupled ColA path).
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp

from .model import (RANK, adapter_param_shapes, ce_labels, lm_forward,
                    lm_logits, lm_param_names, lm_param_shapes, masked_ce,
                    masked_token_acc, seqcls_logits)

PROMPT_LEN = 8     # prompt/p-tuning virtual tokens
PREFIX_LEN = 8     # prefix-tuning K/V positions
PTUNE_HIDDEN = 32  # p-tuning reparameterization MLP hidden size


def tunable_shapes(cfg, method: str, n_classes=None):
    """Ordered tunable-parameter shapes per baseline method."""
    d, L, dff, v = cfg["d"], cfg["layers"], cfg["dff"], cfg["vocab"]
    shapes = OrderedDict()
    if method == "ft":
        shapes.update(lm_param_shapes(cfg))
    elif method == "lora":
        shapes.update(adapter_param_shapes(cfg, "lowrank"))
    elif method == "ia3":
        for i in range(L):
            shapes[f"l{i}.lk"] = (d,)
            shapes[f"l{i}.lv"] = (d,)
            shapes[f"l{i}.lff"] = (dff,)
    elif method == "prompt":
        shapes["prompt"] = (PROMPT_LEN, d)
    elif method == "ptuning":
        # p-tuning: prompt produced by a small MLP over learned anchors
        shapes["anchor"] = (PROMPT_LEN, d)
        shapes["pt.W1"] = (d, PTUNE_HIDDEN)
        shapes["pt.b1"] = (PTUNE_HIDDEN,)
        shapes["pt.W2"] = (PTUNE_HIDDEN, d)
        shapes["pt.b2"] = (d,)
    elif method == "prefix":
        for i in range(L):
            shapes[f"l{i}.pk"] = (PREFIX_LEN, d)
            shapes[f"l{i}.pv"] = (PREFIX_LEN, d)
    else:
        raise ValueError(method)
    if n_classes is not None:
        shapes["head.W"] = (d, n_classes)
    return shapes


def init_tunables(cfg, method: str, n_classes=None, seed: int = 2):
    shapes = tunable_shapes(cfg, method, n_classes)
    key = jax.random.PRNGKey(seed)
    out = OrderedDict()
    for name, shp in shapes.items():
        key, sub = jax.random.split(key)
        if method == "ft":
            # FT starts from the pretrained stand-in; Rust passes those in.
            out[name] = jnp.zeros(shp, jnp.float32)
        elif name.endswith((".A", ".W1")) or name in ("prompt", "anchor") \
                or name.startswith(("pt.W",)) or ".p" in name:
            out[name] = 0.1 * jax.random.normal(sub, shp, jnp.float32)
        elif name.endswith((".lk", ".lv", ".lff")):
            out[name] = jnp.ones(shp, jnp.float32)  # IA3 starts at identity
        else:
            out[name] = jnp.zeros(shp, jnp.float32)
    return out


def _forward_for_method(params, tunables, tokens, cfg, method, use_pallas):
    """Dispatch the forward pass for a baseline method (causal)."""
    L = cfg["layers"]
    if method == "ft":
        p2 = OrderedDict((k, tunables[k]) for k in lm_param_names(cfg))
        hidden, _ = lm_forward(p2, tokens, cfg, use_pallas=use_pallas)
        return hidden, p2, 0
    if method == "lora":
        hidden, _ = lm_forward(params, tokens, cfg, kind="lowrank",
                               adapters=tunables, use_pallas=use_pallas)
        return hidden, params, 0
    if method == "ia3":
        hidden, _ = lm_forward(params, tokens, cfg, ia3=tunables,
                               use_pallas=use_pallas)
        return hidden, params, 0
    if method == "prompt":
        hidden, _ = lm_forward(params, tokens, cfg, prompt=tunables["prompt"],
                               use_pallas=False)
        return hidden, params, PROMPT_LEN
    if method == "ptuning":
        pr = jnp.maximum(tunables["anchor"] @ tunables["pt.W1"] + tunables["pt.b1"],
                         0.0) @ tunables["pt.W2"] + tunables["pt.b2"]
        hidden, _ = lm_forward(params, tokens, cfg, prompt=pr, use_pallas=False)
        return hidden, params, PROMPT_LEN
    if method == "prefix":
        bsz = tokens.shape[0]
        kvp = [(jnp.broadcast_to(tunables[f"l{i}.pk"][None], (bsz, PREFIX_LEN, cfg["d"])),
                jnp.broadcast_to(tunables[f"l{i}.pv"][None], (bsz, PREFIX_LEN, cfg["d"])))
               for i in range(L)]
        hidden, _ = lm_forward(params, tokens, cfg, kv_prefixes=kvp,
                               use_pallas=False)
        return hidden, params, 0
    raise ValueError(method)


def make_coupled_clm_step(cfg, method: str, use_pallas: bool = True):
    """fn(weights..., tunables..., tokens, targets, mask) ->
    (loss, acc, grads-of-tunables...).

    For method='ft' the frozen weights are NOT inputs (FT never reads
    them; XLA would prune the unused parameters and desync the manifest).
    """
    wnames = lm_param_names(cfg) if method != "ft" else []
    wshapes = lm_param_shapes(cfg)
    tshapes = tunable_shapes(cfg, method)
    tnames = list(tshapes.keys())
    bsz, s = cfg["batch"], cfg["seq"]

    def fn(*args):
        params = OrderedDict(zip(wnames, args[: len(wnames)]))
        tun = OrderedDict(zip(tnames, args[len(wnames): len(wnames) + len(tnames)]))
        tokens, targets, mask = args[len(wnames) + len(tnames):]

        def loss_fn(tun):
            hidden, head_p, p = _forward_for_method(params, tun, tokens, cfg,
                                                    method, use_pallas)
            logits = lm_logits(head_p, hidden)
            if p:
                logits = logits[:, p:, :]  # drop prompt positions
            return masked_ce(logits, targets, mask), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(tun)
        acc = masked_token_acc(logits, targets, mask)
        return (loss, acc) + tuple(grads[n] for n in tnames)

    input_names = wnames + tnames + ["tokens", "targets", "mask"]
    specs = [jax.ShapeDtypeStruct(wshapes[n], jnp.float32) for n in wnames]
    specs += [jax.ShapeDtypeStruct(tshapes[n], jnp.float32) for n in tnames]
    specs += [jax.ShapeDtypeStruct((bsz, s), jnp.int32),
              jax.ShapeDtypeStruct((bsz, s), jnp.int32),
              jax.ShapeDtypeStruct((bsz, s), jnp.float32)]
    onames = ["loss", "acc"] + [f"d.{n}" for n in tnames]
    return fn, input_names, onames, specs


def make_coupled_seqcls_step(cfg, method: str, n_classes: int,
                             use_pallas: bool = True):
    """Sequence-classification coupled step (bidirectional trunk + head).

    fn(weights..., tunables..., tokens, labels, mask) ->
    (loss, acc, grads...). The head is always part of the tunables.
    For method='ft' the frozen weights are not inputs (see CLM note).
    """
    wnames = lm_param_names(cfg) if method != "ft" else []
    wshapes = lm_param_shapes(cfg)
    tshapes = tunable_shapes(cfg, method, n_classes=n_classes)
    tnames = list(tshapes.keys())
    bsz, s = cfg["batch"], cfg["seq"]

    def fn(*args):
        params = OrderedDict(zip(wnames, args[: len(wnames)]))
        tun = OrderedDict(zip(tnames, args[len(wnames): len(wnames) + len(tnames)]))
        tokens, labels, mask = args[len(wnames) + len(tnames):]

        def loss_fn(tun):
            body = OrderedDict((k, v) for k, v in tun.items() if k != "head.W")
            if method == "ft":
                p2 = OrderedDict((k, body[k]) for k in lm_param_names(cfg))
                hidden, _ = lm_forward(p2, tokens, cfg, causal=False,
                                       use_pallas=use_pallas)
                pmask = mask
            elif method == "lora":
                hidden, _ = lm_forward(params, tokens, cfg, kind="lowrank",
                                       adapters=body, causal=False,
                                       use_pallas=use_pallas)
                pmask = mask
            elif method == "ia3":
                hidden, _ = lm_forward(params, tokens, cfg, ia3=body,
                                       causal=False, use_pallas=use_pallas)
                pmask = mask
            elif method in ("prompt", "ptuning"):
                if method == "prompt":
                    pr = body["prompt"]
                else:
                    pr = jnp.maximum(body["anchor"] @ body["pt.W1"] + body["pt.b1"],
                                     0.0) @ body["pt.W2"] + body["pt.b2"]
                hidden, _ = lm_forward(params, tokens, cfg, prompt=pr,
                                       causal=False, use_pallas=False)
                ones = jnp.ones((bsz, PROMPT_LEN), jnp.float32)
                pmask = jnp.concatenate([ones, mask], axis=1)
            elif method == "prefix":
                kvp = [(jnp.broadcast_to(body[f"l{i}.pk"][None],
                                         (bsz, PREFIX_LEN, cfg["d"])),
                        jnp.broadcast_to(body[f"l{i}.pv"][None],
                                         (bsz, PREFIX_LEN, cfg["d"])))
                       for i in range(cfg["layers"])]
                hidden, _ = lm_forward(params, tokens, cfg, kv_prefixes=kvp,
                                       causal=False, use_pallas=False)
                pmask = mask
            else:
                raise ValueError(method)
            _, logits = seqcls_logits(hidden, pmask, tun["head.W"])
            return ce_labels(logits, labels), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(tun)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return (loss, acc) + tuple(grads[n] for n in tnames)

    input_names = wnames + tnames + ["tokens", "labels", "mask"]
    specs = [jax.ShapeDtypeStruct(wshapes[n], jnp.float32) for n in wnames]
    specs += [jax.ShapeDtypeStruct(tshapes[n], jnp.float32) for n in tnames]
    specs += [jax.ShapeDtypeStruct((bsz, s), jnp.int32),
              jax.ShapeDtypeStruct((bsz,), jnp.int32),
              jax.ShapeDtypeStruct((bsz, s), jnp.float32)]
    onames = ["loss", "acc"] + [f"d.{n}" for n in tnames]
    return fn, input_names, onames, specs
