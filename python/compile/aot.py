"""AOT driver: lower every L2 graph to ``artifacts/*.hlo.txt`` + manifest.

Run once at build time (``make artifacts``); the Rust coordinator is
self-contained afterwards. Emits:

  artifacts/<name>.hlo.txt        one HLO-text module per graph
  artifacts/manifest.json         name -> {file, inputs, outputs} with
                                  [name, dtype, dims] triples in call order
  artifacts/init/<group>/<p>.bin  raw little-endian f32 initial values
                                  (base weights, adapter + tunable inits)

Usage: python -m compile.aot --out ../artifacts [--sizes tiny,small,base]
       [--filter regex] [--skip-init]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import time

import jax.numpy as jnp
import numpy as np

from . import adapter_update, baselines, ic_models, model
from .hlo import lower_to_hlo_text

N_CLASSES_SEQCLS = 4
BASELINE_METHODS = ["ft", "lora", "ia3", "prompt", "ptuning", "prefix"]


def _spec_entry(name, spec):
    return [name, str(spec.dtype), list(spec.shape)]


class Emitter:
    def __init__(self, out_dir, filter_re=None):
        self.out_dir = out_dir
        self.filter_re = re.compile(filter_re) if filter_re else None
        self.manifest = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, builder):
        if self.filter_re and not self.filter_re.search(name):
            return
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        fn, in_names, out_names, specs = builder()
        text = lower_to_hlo_text(fn, specs)
        with open(path, "w") as f:
            f.write(text)
        self.manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_spec_entry(n, s) for n, s in zip(in_names, specs)],
            "outputs": out_names,
        }
        print(f"  [{time.time()-t0:6.1f}s] {name}  ({len(text)//1024} KiB)")


def export_init(out_dir, group, tree):
    d = os.path.join(out_dir, "init", group)
    os.makedirs(d, exist_ok=True)
    index = {}
    for name, arr in tree.items():
        fname = name.replace("/", "_") + ".bin"
        np.asarray(arr, dtype=np.float32).tofile(os.path.join(d, fname))
        index[name] = {"file": fname, "shape": list(np.shape(arr))}
    with open(os.path.join(d, "index.json"), "w") as f:
        json.dump(index, f, indent=1)


def emit_lm_size(em, size, cfg, *, full=True):
    # perf (§Perf): tiny keeps Pallas attention (kernel-integration
    # coverage); larger sizes lower attention/LN via jnp (1.7x faster on
    # CPU PJRT; the adapter/fit Pallas kernels remain in all artifacts)
    model.ATTN_PALLAS = size == "tiny"
    kinds = ["lowrank", "linear", "mlp", "none"] if full else ["none", "linear"]
    for kind in kinds:
        em.emit(f"lm_fwdbwd_{size}_{kind}",
                lambda k=kind: model.make_lm_fwdbwd(cfg, k))
    em.emit(f"lm_fwd_{size}", lambda: model.make_lm_fwd(cfg))
    # worker fit graphs for this width (shared by q/v sites)
    d, rows = cfg["d"], cfg["batch"] * cfg["seq"]
    fit_kinds = ["lowrank", "linear", "mlp"] if full else ["linear"]
    for kind in fit_kinds:
        em.emit(f"fit_{kind}_{d}x{d}_n{rows}",
                lambda k=kind: adapter_update.make_fit_grad(k, d, d, rows))


def emit_tiny_extras(em, cfg):
    """Artifacts only the tiny size needs: seq-cls task graphs, coupled
    baselines, batch-size variants for the computation-eval bench."""
    size = "tiny"
    model.ATTN_PALLAS = True
    for kind in ["lowrank", "linear", "mlp", "none"]:
        em.emit(f"seqcls_fwdbwd_{size}_{kind}",
                lambda k=kind: model.make_seqcls_fwdbwd(cfg, k, N_CLASSES_SEQCLS))
    for meth in BASELINE_METHODS:
        em.emit(f"coupled_clm_{size}_{meth}",
                lambda m=meth: baselines.make_coupled_clm_step(cfg, m))
        em.emit(f"coupled_seqcls_{size}_{meth}",
                lambda m=meth: baselines.make_coupled_seqcls_step(
                    cfg, m, N_CLASSES_SEQCLS))
    # head-site fit (classifier trained from scratch through a Linear
    # ColA adapter, B rows per batch)
    em.emit(f"fit_linear_{cfg['d']}x{N_CLASSES_SEQCLS}_n{cfg['batch']}",
            lambda: adapter_update.make_fit_grad(
                "linear", cfg["d"], N_CLASSES_SEQCLS, cfg["batch"]))
    # batch variants for Tables 10-18 (memory/runtime sweep)
    for b in (1, 32):
        cb = dict(cfg, batch=b)
        em.emit(f"lm_fwdbwd_{size}_lowrank_b{b}",
                lambda c=cb: model.make_lm_fwdbwd(c, "lowrank"))
        em.emit(f"lm_fwdbwd_{size}_none_b{b}",
                lambda c=cb: model.make_lm_fwdbwd(c, "none"))
        em.emit(f"coupled_clm_{size}_lora_b{b}",
                lambda c=cb: baselines.make_coupled_clm_step(c, "lora"))
        em.emit(f"coupled_clm_{size}_ft_b{b}",
                lambda c=cb: baselines.make_coupled_clm_step(c, "ft"))
        d, rows = cfg["d"], b * cfg["seq"]
        em.emit(f"fit_lowrank_{d}x{d}_n{rows}",
                lambda r=rows, dd=d: adapter_update.make_fit_grad(
                    "lowrank", dd, dd, r))


def emit_ic(em, batch=32):
    for m in ["linear", "mlp", "cnn"]:
        for kind in ["lowrank", "linear", "mlp"]:
            em.emit(f"ic_{m}_fwdbwd_{kind}",
                    lambda mm=m, k=kind: ic_models.make_ic_fwdbwd(mm, k, batch))
        em.emit(f"ic_{m}_fwdbwd_merged",
                lambda mm=m: ic_models.make_ic_fwdbwd_merged(mm, batch))
        for meth in ["ft", "lora"]:
            em.emit(f"ic_{m}_coupled_{meth}",
                    lambda mm=m, me=meth: ic_models.make_ic_coupled(mm, me, batch))
        # fit graphs for every site shape of this model
        for site, (din, dout, rows) in ic_models.ic_site_dims(m).items():
            n = batch * rows
            for kind in ["lowrank", "linear", "mlp"]:
                em.emit(f"fit_{kind}_{din}x{dout}_n{n}",
                        lambda k=kind, a=din, b=dout, nn=n:
                        adapter_update.make_fit_grad(k, a, b, nn))


def emit_opt_refs(em):
    for n in (64, 1024):
        em.emit(f"adamw_n{n}", lambda nn=n: adapter_update.make_adamw_step(nn))
        em.emit(f"sgd_n{n}", lambda nn=n: adapter_update.make_sgd_step(nn))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    ap.add_argument("--filter", default=None)
    ap.add_argument("--skip-init", action="store_true")
    args = ap.parse_args()

    sizes = args.sizes.split(",")
    em = Emitter(args.out, args.filter)
    t0 = time.time()

    for size in sizes:
        cfg = dict(model.CONFIGS[size], batch=8)
        print(f"== {size}: {cfg}")
        emit_lm_size(em, size, cfg, full=(size != "base"))
        if size == "tiny":
            emit_tiny_extras(em, cfg)
    if not args.filter or "ic_" in args.filter or re.search("fit", args.filter or ""):
        emit_ic(em)
    emit_opt_refs(em)

    manifest_path = os.path.join(args.out, "manifest.json")
    existing = {}
    if os.path.exists(manifest_path) and args.filter:
        with open(manifest_path) as f:
            existing = json.load(f).get("artifacts", {})
    existing.update(em.manifest)
    configs = {s: dict(model.CONFIGS[s], batch=8) for s in model.CONFIGS}
    with open(manifest_path, "w") as f:
        json.dump({"artifacts": existing, "configs": configs,
                   "rank": model.RANK, "mlp_hidden": model.MLP_HIDDEN,
                   "n_classes_seqcls": N_CLASSES_SEQCLS,
                   "prompt_len": baselines.PROMPT_LEN,
                   "prefix_len": baselines.PREFIX_LEN}, f, indent=1)

    if not args.skip_init:
        print("== exporting initial values")
        for size in sizes:
            cfg = dict(model.CONFIGS[size], batch=8)
            export_init(args.out, f"lm_{size}", model.init_lm_params(cfg))
            for kind in ["lowrank", "linear", "mlp"]:
                export_init(args.out, f"adapters_{size}_{kind}",
                            model.init_adapter_params(cfg, kind))
            if size == "tiny":
                for meth in BASELINE_METHODS:
                    export_init(args.out, f"tunables_{size}_{meth}",
                                baselines.init_tunables(cfg, meth))
                    export_init(args.out, f"tunables_seqcls_{size}_{meth}",
                                baselines.init_tunables(
                                    cfg, meth, n_classes=N_CLASSES_SEQCLS))
        for m in ["linear", "mlp", "cnn"]:
            export_init(args.out, f"ic_base_{m}", ic_models.init_ic_base(m))
            for kind in ["lowrank", "linear", "mlp"]:
                export_init(args.out, f"ic_{m}_{kind}",
                            ic_models.init_ic_adapters(m, kind))

    print(f"done: {len(em.manifest)} artifacts in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
