"""Pallas kernels for adapter application (the server-side hot spot).

These implement the fused forward of ColA adapters:

  lora_apply   : h + scale * (x @ A) @ B        (low-rank, LoRA-shaped)
  linear_apply : h + scale * x @ W              (full-matrix, Prop.2 class)

TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of a CUDA
threadblock per output tile, we express the HBM<->VMEM schedule with a
BlockSpec grid over row blocks. The rank-r intermediate ``x @ A`` lives
entirely in VMEM (registers/scratch under interpret mode) and never
round-trips HBM — that is the fusion the paper gets implicitly from
cuBLAS call ordering. A and B are small enough to be resident per block
(d*r + r*d floats), so the kernel is a single pass over x/h rows feeding
the MXU with (block_n x d_in) @ (d_in x r) and (block_n x r) @ (r x d_out)
matmuls.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode lowers them to plain HLO
(see /opt/xla-example/README.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 128


def _pad_rows(arr, block_n):
    n = arr.shape[0]
    rem = n % block_n
    if rem == 0:
        return arr, n
    pad = block_n - rem
    return jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1)), n


def _lora_apply_kernel(x_ref, a_ref, b_ref, h_ref, o_ref, *, scale):
    # One row block: (bn, d_in) @ (d_in, r) stays in VMEM, then (bn, r) @
    # (r, d_out). f32 accumulation on the MXU.
    xa = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = h_ref[...] + scale * jnp.dot(
        xa, b_ref[...], preferred_element_type=jnp.float32
    ).astype(h_ref.dtype)


def lora_apply(x, a, b, h, scale, *, block_n: int = DEFAULT_BLOCK_N):
    """Fused ``h + scale * (x @ a) @ b`` over row blocks of x/h.

    x: (n, d_in), a: (d_in, r), b: (r, d_out), h: (n, d_out) -> (n, d_out).
    """
    (n, d_in), (_, r), (_, d_out) = x.shape, a.shape, b.shape
    bn = min(block_n, n)
    xp, n0 = _pad_rows(x, bn)
    hp, _ = _pad_rows(h, bn)
    grid = (xp.shape[0] // bn,)
    out = pl.pallas_call(
        functools.partial(_lora_apply_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
            pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], d_out), h.dtype),
        interpret=True,
    )(xp, a, b, hp)
    return out[:n0]


def _linear_apply_kernel(x_ref, w_ref, h_ref, o_ref, *, scale):
    o_ref[...] = h_ref[...] + scale * jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(h_ref.dtype)


def linear_apply(x, w, h, scale, *, block_n: int = DEFAULT_BLOCK_N):
    """Fused ``h + scale * x @ w`` (full-matrix adapter) over row blocks."""
    (n, d_in), (_, d_out) = x.shape, w.shape
    bn = min(block_n, n)
    xp, n0 = _pad_rows(x, bn)
    hp, _ = _pad_rows(h, bn)
    grid = (xp.shape[0] // bn,)
    out = pl.pallas_call(
        functools.partial(_linear_apply_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], d_out), h.dtype),
        interpret=True,
    )(xp, w, hp)
    return out[:n0]
