"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to fp error) pure
``jax.numpy`` counterpart here. ``python/tests/test_kernels.py`` sweeps
shapes/dtypes with hypothesis and asserts allclose between the Pallas
(interpret=True) output and these functions. The references are also the
semantic spec: anything unclear about a kernel is defined by its ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_apply_ref(x, a, b, h, scale):
    """h + scale * (x @ a) @ b  — fused low-rank adapter application.

    x: (n, d_in), a: (d_in, r), b: (r, d_out), h: (n, d_out).
    """
    return h + scale * (x @ a) @ b


def linear_apply_ref(x, w, h, scale):
    """h + scale * x @ w — full-matrix (Prop.2 mergeable) adapter."""
    return h + scale * x @ w


def fit_step_lowrank_ref(x, target, a, b, scale):
    """Gradients of the GL surrogate loss for a low-rank adapter.

    l(w) = 1/2 sum_i ||scale*(x_i @ a) @ b - target_i||^2   (SUM reduction:
    the targets are built from the gradient of the *mean* task loss, so a
    sum here reproduces the coupled parameter gradient exactly — Prop. 1.)

    Returns (da, db).
    """
    xa = x @ a                       # (n, r)
    res = scale * xa @ b - target    # (n, d_out)
    da = scale * x.T @ (res @ b.T)   # (d_in, r)
    db = scale * xa.T @ res          # (r, d_out)
    return da, db


def fit_step_linear_ref(x, target, w, scale):
    """Gradient of the GL surrogate for a full linear adapter. Returns dw."""
    res = scale * x @ w - target
    return scale * x.T @ res


def fit_step_mlp_ref(x, target, w1, b1, w2, b2):
    """Gradients of the GL surrogate for a 2-layer ReLU MLP adapter.

    g(x) = relu(x @ w1 + b1) @ w2 + b2. Returns (dw1, db1, dw2, db2).
    """
    z = x @ w1 + b1
    hmid = jnp.maximum(z, 0.0)
    res = hmid @ w2 + b2 - target          # (n, d_out)
    dw2 = hmid.T @ res
    db2 = jnp.sum(res, axis=0)
    dmid = (res @ w2.T) * (z > 0.0)
    dw1 = x.T @ dmid
    db1 = jnp.sum(dmid, axis=0)
    return dw1, db1, dw2, db2


def attention_ref(q, k, v, causal: bool):
    """Single-head scaled dot-product attention, optional causal mask.

    q,k,v: (s, dh). Numerically stable softmax, f32 accumulation.
    """
    s, dh = q.shape
    logits = (q @ k.T) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Row-wise layer norm. x: (n, d)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
