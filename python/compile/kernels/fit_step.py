"""Pallas kernels for the GL surrogate fit step (the worker-side hot spot).

This is the computation the paper offloads to low-cost devices: the
gradient of the quadratic surrogate

    l_m(w) = 1/2 sum_i || g_w(x_i) - (dh_i - grad_hhat_i) ||^2

evaluated at the current w (Eq. 6). By Prop. 1 this gradient equals the
coupled parameter gradient of the task loss, so these kernels + an
optimizer step ARE ColA's decoupled update.

Fusion structure (DESIGN.md §Hardware-Adaptation): the paper runs this as
three cuBLAS GEMMs plus elementwise residual work on a CPU/low-end GPU.
Here each row block performs residual computation and both contraction
GEMMs in one VMEM-resident pass, accumulating da/db across the grid —
the accumulators are the revisited output blocks (constant index_map), a
standard Pallas reduction idiom that keeps the (d_in x r) and (r x d_out)
accumulators pinned in VMEM for the whole sweep.

interpret=True everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 128


def _pad_rows(arr, block_n):
    n = arr.shape[0]
    rem = n % block_n
    if rem == 0:
        return arr, n
    pad = block_n - rem
    return jnp.pad(arr, ((0, pad),) + ((0, 0),) * (arr.ndim - 1)), n


def _fit_lowrank_kernel(x_ref, t_ref, a_ref, b_ref, da_ref, db_ref, *, scale):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...]
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    res = scale * jnp.dot(xa, b_ref[...], preferred_element_type=jnp.float32)
    res = res - t_ref[...]
    # da += scale * x^T (res B^T); db += scale * (xA)^T res
    da_ref[...] += scale * jnp.dot(
        x.T, jnp.dot(res, b_ref[...].T, preferred_element_type=jnp.float32),
        preferred_element_type=jnp.float32,
    )
    db_ref[...] += scale * jnp.dot(xa.T, res, preferred_element_type=jnp.float32)


def fit_step_lowrank(x, target, a, b, scale, *, block_n: int = DEFAULT_BLOCK_N):
    """Surrogate-loss gradients (da, db) for a low-rank adapter.

    x: (n, d_in), target: (n, d_out) = dh - grad_hhat, a: (d_in, r),
    b: (r, d_out). SUM reduction over rows (see ref.fit_step_lowrank_ref).
    Zero-padded rows contribute exactly zero gradient.
    """
    (n, d_in), (_, r), (_, d_out) = x.shape, a.shape, b.shape
    bn = min(block_n, n)
    xp, _ = _pad_rows(x, bn)
    tp, _ = _pad_rows(target, bn)
    grid = (xp.shape[0] // bn,)
    da, db = pl.pallas_call(
        functools.partial(_fit_lowrank_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_in), lambda i: (i, 0)),
            pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, r), jnp.float32),
            jax.ShapeDtypeStruct((r, d_out), jnp.float32),
        ],
        interpret=True,
    )(xp, tp, a, b)
    return da, db


def _fit_linear_kernel(x_ref, t_ref, w_ref, dw_ref, *, scale):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    x = x_ref[...]
    res = scale * jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    res = res - t_ref[...]
    dw_ref[...] += scale * jnp.dot(x.T, res, preferred_element_type=jnp.float32)


def fit_step_linear(x, target, w, scale, *, block_n: int = DEFAULT_BLOCK_N):
    """Surrogate-loss gradient dw for a full linear adapter."""
    (n, d_in), (_, d_out) = x.shape, w.shape
    bn = min(block_n, n)
    xp, _ = _pad_rows(x, bn)
    tp, _ = _pad_rows(target, bn)
    grid = (xp.shape[0] // bn,)
    return pl.pallas_call(
        functools.partial(_fit_linear_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_in), lambda i: (i, 0)),
            pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_in, d_out), jnp.float32),
        interpret=True,
    )(xp, tp, w)


def _fit_mlp_kernel(x_ref, t_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                    dw1_ref, db1_ref, dw2_ref, db2_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dw1_ref[...] = jnp.zeros_like(dw1_ref)
        db1_ref[...] = jnp.zeros_like(db1_ref)
        dw2_ref[...] = jnp.zeros_like(dw2_ref)
        db2_ref[...] = jnp.zeros_like(db2_ref)

    x = x_ref[...]
    z = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    hmid = jnp.maximum(z, 0.0)
    res = jnp.dot(hmid, w2_ref[...], preferred_element_type=jnp.float32)
    res = res + b2_ref[...] - t_ref[...]
    dw2_ref[...] += jnp.dot(hmid.T, res, preferred_element_type=jnp.float32)
    db2_ref[...] += jnp.sum(res, axis=0, keepdims=True)
    dmid = jnp.dot(res, w2_ref[...].T, preferred_element_type=jnp.float32)
    dmid = dmid * (z > 0.0)
    dw1_ref[...] += jnp.dot(x.T, dmid, preferred_element_type=jnp.float32)
    db1_ref[...] += jnp.sum(dmid, axis=0, keepdims=True)


def fit_step_mlp(x, target, w1, b1, w2, b2, *, block_n: int = DEFAULT_BLOCK_N):
    """Surrogate-loss gradients for the 2-layer ReLU MLP adapter.

    Biases are passed/returned with shape (1, d) so every ref is 2-D
    (TPU-friendly layout; avoids 1-D vregs). Padded rows: x=0 gives
    z=b1, hmid=relu(b1), res=g(0)-0 ... NOT zero — so unlike the linear
    kernels, MLP padding must be handled by masking. We mask via a row
    validity test built from the target: padded targets are all-zero AND
    padded x is all-zero, so we zero dmid/res contributions for padded
    rows explicitly using the row index.
    """
    (n, d_in), (_, dh) = x.shape, w1.shape
    d_out = w2.shape[1]
    bn = min(block_n, n)
    if n % bn != 0:
        # MLP bias terms make zero-padding non-neutral; fall back to a
        # single unblocked pass (worker batches are interval-sized and
        # controlled by the coordinator, so this path is rare).
        bn = n
    grid = (x.shape[0] // bn,)
    dw1, db1, dw2, db2 = pl.pallas_call(
        _fit_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_in), lambda i: (i, 0)),
            pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
            pl.BlockSpec((d_in, dh), lambda i: (0, 0)),
            pl.BlockSpec((1, dh), lambda i: (0, 0)),
            pl.BlockSpec((dh, d_out), lambda i: (0, 0)),
            pl.BlockSpec((1, d_out), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d_in, dh), lambda i: (0, 0)),
            pl.BlockSpec((1, dh), lambda i: (0, 0)),
            pl.BlockSpec((dh, d_out), lambda i: (0, 0)),
            pl.BlockSpec((1, d_out), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, dh), jnp.float32),
            jax.ShapeDtypeStruct((1, dh), jnp.float32),
            jax.ShapeDtypeStruct((dh, d_out), jnp.float32),
            jax.ShapeDtypeStruct((1, d_out), jnp.float32),
        ],
        interpret=True,
    )(x, target, w1, b1.reshape(1, -1), w2, b2.reshape(1, -1))
    return dw1, db1.reshape(-1), dw2, db2.reshape(-1)
