"""Pallas flash-style attention kernel (base-model compute hot spot).

Row-block online-softmax attention for a single head. The grid walks query
row blocks; K and V for the whole sequence are VMEM-resident per block
(S*dh floats each — e.g. S=256, dh=64: 128 KiB for K+V, comfortably inside
the ~16 MiB VMEM budget; the block table in DESIGN.md §Perf sizes this for
the configs we lower). The (block_q x S) logit tile is formed on the MXU,
the numerically-stable softmax runs in-block, and the (block_q x dh)
output tile accumulates in f32.

This is the TPU re-think of the paper's GPU attention: no shared-memory
K/V staging loop per threadblock — one BlockSpec per operand expresses the
whole HBM->VMEM schedule.

interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_Q = 64


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, block_q, scale):
    i = pl.program_id(0)
    q = q_ref[...]                                 # (bq, dh)
    k = k_ref[...]                                 # (s, dh)
    v = v_ref[...]                                 # (s, dh)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        s = k.shape[0]
        row = i * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(col <= row, logits, jnp.finfo(jnp.float32).min)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def attention(q, k, v, causal: bool, *, block_q: int = DEFAULT_BLOCK_Q):
    """Single-head attention. q,k,v: (s, dh) -> (s, dh).

    Requires s % block_q == 0 (the coordinator only lowers power-of-two
    sequence lengths); asserts otherwise at trace time.
    """
    s, dh = q.shape
    bq = min(block_q, s)
    if s % bq != 0:
        raise ValueError(f"seq len {s} not divisible by block_q {bq}")
    scale = 1.0 / (dh ** 0.5)
    return pl.pallas_call(
        functools.partial(_attention_kernel, causal=causal, block_q=bq, scale=scale),
        grid=(s // bq,),
        in_specs=[
            pl.BlockSpec((bq, dh), lambda i: (i, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, dh), q.dtype),
        interpret=True,
    )(q, k, v)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = ((x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]).astype(
        o_ref.dtype
    )


def layernorm(x, gamma, beta, *, eps: float = 1e-5, block_n: int = 128):
    """Row-wise layernorm over row blocks. x: (n, d)."""
    n, d = x.shape
    bn = min(block_n, n)
    rem = n % bn
    xp = jnp.pad(x, ((0, bn - rem), (0, 0))) if rem else x
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(xp.shape[0] // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], d), x.dtype),
        interpret=True,
    )(xp, gamma.reshape(1, -1), beta.reshape(1, -1))
    return out[:n]
