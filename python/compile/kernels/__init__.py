"""L1: Pallas kernels for ColA's compute hot-spots + pure-jnp oracles.

- ``lora.py``      -- fused adapter application (server forward path)
- ``fit_step.py``  -- fused GL surrogate gradients (worker update path)
- ``attention.py`` -- flash-style attention + layernorm (base model)
- ``ref.py``       -- pure-jnp reference oracles (the semantic spec)

All kernels are lowered with ``interpret=True`` so the resulting HLO runs
on the CPU PJRT client the Rust runtime uses.
"""
from . import attention, fit_step, lora, ref  # noqa: F401
