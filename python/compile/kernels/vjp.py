"""custom_vjp wrappers: Pallas forward + exact backward.

``pl.pallas_call`` has no automatic reverse-mode rule, but the ColA server
graph differentiates *through* every kernel on the path from the loss to
the epsilon probes. Each wrapper here pairs the Pallas forward with an
explicit VJP:

- adapter applies: dx reuses the *same Pallas kernels* with transposed
  operands (``dx = s*(g @ B^T) @ A^T`` is just ``lora_apply`` again); the
  dA/dB cotangents are written as plain matmuls — in the decoupled server
  artifact they are dead code (the loss is differentiated w.r.t. eps
  only) and XLA DCEs them; in the coupled-LoRA baseline they are the
  standard LoRA gradients.
- attention: flash-style rematerializing backward (save q,k,v, recompute
  the probability tile) in jnp; forward stays the Pallas kernel.
- layernorm: standard fused backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as katt
from . import lora as klora


# -- low-rank adapter apply --------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lora_apply(x, a, b, h, scale):
    return klora.lora_apply(x, a, b, h, scale)


def _lora_fwd(x, a, b, h, scale):
    return klora.lora_apply(x, a, b, h, scale), (x, a, b)


def _lora_bwd(scale, res, g):
    x, a, b = res
    # dx via the same Pallas kernel, transposed: s*(g@B^T)@A^T
    dx = klora.lora_apply(g, b.T, a.T, jnp.zeros_like(x), scale)
    xa = x @ a
    da = scale * x.T @ (g @ b.T)
    db = scale * xa.T @ g
    return dx, da, db, g


lora_apply.defvjp(_lora_fwd, _lora_bwd)


# -- full-matrix adapter apply ------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear_apply(x, w, h, scale):
    return klora.linear_apply(x, w, h, scale)


def _linear_fwd(x, w, h, scale):
    return klora.linear_apply(x, w, h, scale), (x, w)


def _linear_bwd(scale, res, g):
    x, w = res
    dx = klora.linear_apply(g, w.T, jnp.zeros_like(x), scale)
    dw = scale * x.T @ g
    return dx, dw, g


linear_apply.defvjp(_linear_fwd, _linear_bwd)


# -- attention ----------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal):
    return katt.attention(q, k, v, causal)


def _att_fwd(q, k, v, causal):
    return katt.attention(q, k, v, causal), (q, k, v)


def _att_bwd(causal, res, do):
    q, k, v = res
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    logits = (q @ k.T) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    dv = p.T @ do
    dp = do @ v.T
    dl = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = (dl @ k) * scale
    dk = (dl.T @ q) * scale
    return dq, dk, dv


attention.defvjp(_att_fwd, _att_bwd)


# -- layernorm ------------------------------------------------------------------

EPS = 1e-5


@jax.custom_vjp
def layernorm(x, gamma, beta):
    return katt.layernorm(x, gamma, beta, eps=EPS)


def _ln_fwd(x, gamma, beta):
    return katt.layernorm(x, gamma, beta, eps=EPS), (x, gamma)


def _ln_bwd(res, g):
    x, gamma = res
    d = x.shape[-1]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = xc * inv
    dgamma = jnp.sum(g * xhat, axis=0)
    dbeta = jnp.sum(g, axis=0)
    gg = g * gamma
    dx = inv * (gg - jnp.mean(gg, axis=-1, keepdims=True)
                - xhat * jnp.mean(gg * xhat, axis=-1, keepdims=True))
    return dx, dgamma, dbeta


layernorm.defvjp(_ln_fwd, _ln_bwd)
