"""Lowering helper: jitted JAX function -> HLO *text* artifact.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""
from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, specs) -> str:
    """Lower ``fn(*specs)`` to HLO text with a tuple root (the Rust side
    unwraps with ``decompose_tuple``)."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
