"""L2: image-classification models for the learning-from-scratch study.

Appendix C.3 trains Linear / MLP / CNN models from scratch with ColA:
the base weights are identically zero and the adapters learn the whole
function (ColA(Linear) == full training without approximation; LoRA's
low-rank bottleneck shows up as the accuracy gap in Table 9 / Figs 2-3).

Convolutions are expressed via **im2col + matmul**, so a conv layer is a
linear site exactly like a projection in the transformer: its hidden
input x_m is the (rows = B*H*W, cols = k*k*C_in) patch matrix and the
same Pallas fit kernels update its adapters. This is also what makes a
conv adapter mergeable under Prop. 2 (conv is linear in its input).

Site inventory:
  ic_linear : fc   (784 -> 10)
  ic_mlp    : fc1  (784 -> 128), fc2 (128 -> 10)
  ic_cnn    : conv1 (9 -> 16), conv2 (144 -> 32), fc (1568 -> 10)
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp

from .kernels import lora as klora
from .model import ADAPTER_SCALE, MLP_HIDDEN, RANK, apply_adapter, ce_labels

IMG = 28          # synthetic image side (MNIST-shaped)
N_CLASSES = 10


def ic_site_dims(model: str):
    """Ordered {site: (d_in, d_out, rows_per_image)}; rows = spatial
    positions contributing rows to the site's im2col/feature matrix."""
    if model == "linear":
        return OrderedDict(fc=(IMG * IMG, N_CLASSES, 1))
    if model == "mlp":
        return OrderedDict(fc1=(IMG * IMG, 128, 1), fc2=(128, N_CLASSES, 1))
    if model == "cnn":
        return OrderedDict(
            conv1=(9, 16, IMG * IMG),          # 3x3x1 patches, SAME pad
            conv2=(16 * 9, 32, 14 * 14),       # after 2x2 avgpool
            fc=(32 * 7 * 7, N_CLASSES, 1),     # after second pool
        )
    raise ValueError(model)


def ic_adapter_shapes(model: str, kind: str):
    shapes = OrderedDict()
    for site, (din, dout, _) in ic_site_dims(model).items():
        if kind == "lowrank":
            r = min(RANK, din, dout)
            shapes[f"{site}.A"] = (din, r)
            shapes[f"{site}.B"] = (r, dout)
        elif kind == "linear":
            shapes[f"{site}.W"] = (din, dout)
        elif kind == "mlp":
            shapes[f"{site}.W1"] = (din, MLP_HIDDEN)
            shapes[f"{site}.b1"] = (MLP_HIDDEN,)
            shapes[f"{site}.W2"] = (MLP_HIDDEN, dout)
            shapes[f"{site}.b2"] = (dout,)
        else:
            raise ValueError(kind)
    return shapes


def init_ic_base(model: str, seed: int = 4):
    """Random base initialization (He-style): 'learning from scratch'
    trains this network via ColA — base frozen, adapters learn the
    update; ColA(Linear) merged is exactly full training (App. C.3)."""
    import numpy as _np
    key = jax.random.PRNGKey(seed)
    out = OrderedDict()
    for site, (din, dout, _) in ic_site_dims(model).items():
        key, sub = jax.random.split(key)
        std = (2.0 / din) ** 0.5
        out[f"{site}.Wbase"] = std * jax.random.normal(sub, (din, dout), jnp.float32)
    return out


def init_ic_adapters(model: str, kind: str, seed: int = 3):
    """Adapter init: A/W1 random + B/W/W2 zero gives g(x)=0 at t=0
    (paper's zero-init convention)."""
    shapes = ic_adapter_shapes(model, kind)
    key = jax.random.PRNGKey(seed)
    out = OrderedDict()
    for name, shp in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith((".A", ".W1")):
            out[name] = (1.0 / shp[0]) ** 0.5 * jax.random.normal(sub, shp, jnp.float32)
        else:
            out[name] = jnp.zeros(shp, jnp.float32)
    return out


def _im2col(x, k=3):
    """x: (B,H,W,C) -> (B,H,W, k*k*C) SAME-padded 3x3 patches."""
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2), (k, k), (1, 1), "SAME")
    # (B, C*k*k, H, W) -> (B,H,W,C*k*k)
    return patches.transpose(0, 2, 3, 1)


def _avgpool2(x):
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def ic_forward(model, kind, aps, images, eps=None, use_pallas=True,
               merged_ws=None, base_ws=None):
    """Forward with frozen random base + adapters (or merged weights).

    images: (B, H, W, 1). Returns (logits, xs dict of 2-D row matrices).
    If merged_ws is given (dict site->W), the model runs as a plain
    parameterized network (merged mode / FT baseline); otherwise every
    site computes x @ W_base + g(x), the ColA from-scratch arrangement.
    """
    eps = eps or {}
    dims = ic_site_dims(model)

    def site_out(site, x2d):
        if merged_ws is not None:
            out = x2d @ merged_ws[site]
        else:
            din, dout, _ = dims[site]
            h0 = (x2d @ base_ws[site] if base_ws is not None
                  else jnp.zeros((x2d.shape[0], dout), jnp.float32))
            out = apply_adapter(kind, aps, site, x2d, h0, use_pallas)
        if site in eps:
            out = out + eps[site]
        return out

    xs = {}
    b = images.shape[0]
    if model == "linear":
        x = images.reshape(b, -1)
        xs["fc"] = x
        return site_out("fc", x), xs
    if model == "mlp":
        x = images.reshape(b, -1)
        xs["fc1"] = x
        hmid = jnp.maximum(site_out("fc1", x), 0.0)
        xs["fc2"] = hmid
        return site_out("fc2", hmid), xs
    if model == "cnn":
        p1 = _im2col(images).reshape(-1, 9)          # (B*28*28, 9)
        xs["conv1"] = p1
        c1 = site_out("conv1", p1).reshape(b, IMG, IMG, 16)
        c1 = _avgpool2(jnp.maximum(c1, 0.0))          # (B,14,14,16)
        p2 = _im2col(c1).reshape(-1, 144)             # (B*14*14, 144)
        xs["conv2"] = p2
        c2 = site_out("conv2", p2).reshape(b, 14, 14, 32)
        c2 = _avgpool2(jnp.maximum(c2, 0.0))          # (B,7,7,32)
        flat = c2.reshape(b, -1)
        xs["fc"] = flat
        return site_out("fc", flat), xs
    raise ValueError(model)


def make_ic_fwdbwd(model: str, kind: str, batch: int, use_pallas=True):
    """Decoupled fwd/bwd: fn(base W..., adapters..., images, labels) ->
    (loss, acc, x_site..., ghat_site...)."""
    dims = ic_site_dims(model)
    ashapes = ic_adapter_shapes(model, kind)
    anames = list(ashapes.keys())
    bnames = [f"{s}.Wbase" for s in dims]

    def fn(*args):
        base = {s: w for s, w in zip(dims, args[: len(bnames)])}
        aps = OrderedDict(zip(anames, args[len(bnames): len(bnames) + len(anames)]))
        images, labels = args[len(bnames) + len(anames):]

        def inner(eps):
            logits, xs = ic_forward(model, kind, aps, images, eps=eps,
                                    use_pallas=use_pallas, base_ws=base)
            return ce_labels(logits, labels), (xs, logits)

        eps0 = {site: jnp.zeros((batch * rows, dout), jnp.float32)
                for site, (_, dout, rows) in dims.items()}
        (loss, (xs, logits)), geps = jax.value_and_grad(inner, has_aux=True)(eps0)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        outs = [loss, acc]
        outs += [xs[s] for s in dims]
        outs += [geps[s] for s in dims]
        return tuple(outs)

    input_names = bnames + anames + ["images", "labels"]
    specs = [jax.ShapeDtypeStruct((dims[s][0], dims[s][1]), jnp.float32)
             for s in dims]
    specs += [jax.ShapeDtypeStruct(ashapes[n], jnp.float32) for n in anames]
    specs += [jax.ShapeDtypeStruct((batch, IMG, IMG, 1), jnp.float32),
              jax.ShapeDtypeStruct((batch,), jnp.int32)]
    onames = (["loss", "acc"] + [f"{s}.x" for s in dims] + [f"{s}.g" for s in dims])
    return fn, input_names, onames, specs


def make_ic_fwdbwd_merged(model: str, batch: int, use_pallas=True):
    """Merged-mode decoupled graph: fn(W_site..., images, labels) -> same
    outputs. The site weights are the merged base+adapter matrices."""
    dims = ic_site_dims(model)
    wnames = [f"{s}.W" for s in dims]

    def fn(*args):
        ws = {s: w for s, w in zip(dims, args[: len(wnames)])}
        images, labels = args[len(wnames):]

        def inner(eps):
            logits, xs = ic_forward(model, "none", {}, images, eps=eps,
                                    use_pallas=use_pallas, merged_ws=ws)
            return ce_labels(logits, labels), (xs, logits)

        eps0 = {site: jnp.zeros((batch * rows, dout), jnp.float32)
                for site, (_, dout, rows) in dims.items()}
        (loss, (xs, logits)), geps = jax.value_and_grad(inner, has_aux=True)(eps0)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        outs = [loss, acc] + [xs[s] for s in dims] + [geps[s] for s in dims]
        return tuple(outs)

    input_names = wnames + ["images", "labels"]
    specs = [jax.ShapeDtypeStruct((dims[s][0], dims[s][1]), jnp.float32)
             for s in dims]
    specs += [jax.ShapeDtypeStruct((batch, IMG, IMG, 1), jnp.float32),
              jax.ShapeDtypeStruct((batch,), jnp.int32)]
    onames = (["loss", "acc"] + [f"{s}.x" for s in dims] + [f"{s}.g" for s in dims])
    return fn, input_names, onames, specs


def make_ic_coupled(model: str, method: str, batch: int, use_pallas=True):
    """Coupled baselines: method='ft' (site weights directly) or
    'lora' (low-rank adapters, autodiff). fn(tunables..., images, labels)
    -> (loss, acc, grads...)."""
    dims = ic_site_dims(model)
    if method == "ft":
        tshapes = OrderedDict((f"{s}.W", (d[0], d[1])) for s, d in dims.items())
    elif method == "lora":
        tshapes = ic_adapter_shapes(model, "lowrank")
    else:
        raise ValueError(method)
    tnames = list(tshapes.keys())

    dims2 = dims
    bnames = [] if method == "ft" else [f"{s}.Wbase" for s in dims2]

    def fn(*args):
        base = {s: w for s, w in zip(dims2, args[: len(bnames)])}
        tun = OrderedDict(zip(tnames, args[len(bnames): len(bnames) + len(tnames)]))
        images, labels = args[len(bnames) + len(tnames):]

        def loss_fn(tun):
            if method == "ft":
                ws = {s: tun[f"{s}.W"] for s in dims2}
                logits, _ = ic_forward(model, "none", {}, images,
                                       use_pallas=use_pallas, merged_ws=ws)
            else:
                logits, _ = ic_forward(model, "lowrank", tun, images,
                                       use_pallas=use_pallas, base_ws=base)
            return ce_labels(logits, labels), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(tun)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return (loss, acc) + tuple(grads[n] for n in tnames)

    input_names = bnames + tnames + ["images", "labels"]
    specs = [jax.ShapeDtypeStruct((dims2[s][0], dims2[s][1]), jnp.float32)
             for s in dims2 if method != "ft"]
    specs += [jax.ShapeDtypeStruct(tshapes[n], jnp.float32) for n in tnames]
    specs += [jax.ShapeDtypeStruct((batch, IMG, IMG, 1), jnp.float32),
              jax.ShapeDtypeStruct((batch,), jnp.int32)]
    onames = ["loss", "acc"] + [f"d.{n}" for n in tnames]
    return fn, input_names, onames, specs
