"""L2: worker-side update graphs — the computation ColA offloads (Eq. 6).

Each graph receives the adaptation data the server shipped —
``x`` (hidden inputs, flattened to rows) and ``ghat`` (gradient of the
fine-tuned hidden representation) — plus the worker's current adapter
parameters, and returns the surrogate-loss gradients:

    target = g_w(x) - ghat          (the worker recomputes dh = g_w(x)
                                     itself, exactly Algorithm 1 line 13)
    grads  = d/dw  1/2 sum_i ||g_w(x_i) - target_i||^2

By Prop. 1 these equal the coupled parameter gradients of the task loss.
The heavy contractions run in the Pallas ``fit_step`` kernels so they
lower into the same HLO artifact.

Gradients (not updated weights) are returned: the Rust worker accumulates
them across the adaptation interval I natively, scales by 1/I, and applies
its own (tested-equivalent) SGD/AdamW — this keeps one artifact valid for
every interval setting. A reference ``adamw_step``/``sgd_step`` graph is
also lowered so the Rust optimizer can be verified bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fit_step as kfit
from .model import ADAPTER_SCALE, MLP_HIDDEN, RANK


def make_fit_grad(kind: str, d_in: int, d_out: int, n_rows: int):
    """Build fn(x, ghat, params...) -> grads... for one adapter site.

    Returns (fn, input_names, output_names, specs).
    """
    s = ADAPTER_SCALE
    xspec = jax.ShapeDtypeStruct((n_rows, d_in), jnp.float32)
    gspec = jax.ShapeDtypeStruct((n_rows, d_out), jnp.float32)

    if kind == "lowrank":
        def fn(x, ghat, a, b):
            delta = s * (x @ a) @ b
            target = delta - ghat
            da, db = kfit.fit_step_lowrank(x, target, a, b, s)
            return (da, db)
        names = ["x", "ghat", "A", "B"]
        specs = [xspec, gspec,
                 jax.ShapeDtypeStruct((d_in, RANK), jnp.float32),
                 jax.ShapeDtypeStruct((RANK, d_out), jnp.float32)]
        onames = ["dA", "dB"]
    elif kind == "linear":
        def fn(x, ghat, w):
            delta = s * x @ w
            target = delta - ghat
            return (kfit.fit_step_linear(x, target, w, s),)
        names = ["x", "ghat", "W"]
        specs = [xspec, gspec, jax.ShapeDtypeStruct((d_in, d_out), jnp.float32)]
        onames = ["dW"]
    elif kind == "mlp":
        def fn(x, ghat, w1, b1, w2, b2):
            delta = s * (jnp.maximum(x @ w1 + b1, 0.0) @ w2 + b2)
            target = delta - ghat
            return kfit.fit_step_mlp(x, target, w1, b1, w2, b2)
        names = ["x", "ghat", "W1", "b1", "W2", "b2"]
        specs = [xspec, gspec,
                 jax.ShapeDtypeStruct((d_in, MLP_HIDDEN), jnp.float32),
                 jax.ShapeDtypeStruct((MLP_HIDDEN,), jnp.float32),
                 jax.ShapeDtypeStruct((MLP_HIDDEN, d_out), jnp.float32),
                 jax.ShapeDtypeStruct((d_out,), jnp.float32)]
        onames = ["dW1", "db1", "dW2", "db2"]
    else:
        raise ValueError(kind)
    return fn, names, onames, specs


def make_adamw_step(n: int):
    """Reference AdamW over a flat f32[n] parameter vector.

    fn(w, g, m, v, t, lr, beta1, beta2, eps, wd) -> (w', m', v')
    t is the 1-based step count (f32 scalar). Matches the paper's AdamW
    (decoupled weight decay) and the Rust-native optimizer bit-for-bit.
    """
    def fn(w, g, m, v, t, lr, beta1, beta2, eps, wd):
        m2 = beta1 * m + (1.0 - beta1) * g
        v2 = beta2 * v + (1.0 - beta2) * g * g
        mhat = m2 / (1.0 - beta1 ** t)
        vhat = v2 / (1.0 - beta2 ** t)
        w2 = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)
        return (w2, m2, v2)

    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    names = ["w", "g", "m", "v", "t", "lr", "beta1", "beta2", "eps", "wd"]
    return fn, names, ["w2", "m2", "v2"], [vec, vec, vec, vec, sc, sc, sc, sc, sc, sc]


def make_sgd_step(n: int):
    """fn(w, g, lr, wd) -> (w',) — plain SGD with decoupled weight decay."""
    def fn(w, g, lr, wd):
        return (w - lr * (g + wd * w),)

    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    return fn, ["w", "g", "lr", "wd"], ["w2"], [vec, vec, sc, sc]
