"""L2: the base-model compute graphs for ColA (build-time JAX, AOT to HLO).

The central artifact is the *decoupled* fwd/bwd graph of Algorithm 1:
given base weights, live adapter parameters (unmerged mode) or merged
weights (merged mode), and a batch, it returns

    loss,  x_{1:M}  (hidden inputs of every adapter site),
           grad_hhat_{1:M}  (gradient of the loss w.r.t. each fine-tuned
                             hidden representation)

and — deliberately — **no parameter gradients**. That is Gradient
Decoupling: the server never materializes grad-w; the Rust coordinator
ships (x_m, grad_hhat_m) to low-cost workers which recover grad-w exactly
via the surrogate loss (Prop. 1, python/compile/adapter_update.py).

grad_hhat extraction uses the epsilon-probe trick: every site output is
``hhat_m = h_m + g_w(x_m) + eps_m`` with ``eps_m = 0``; differentiating
w.r.t. eps_m yields exactly d loss / d hhat_m while keeping hhat itself on
the natural forward path.

Adapter sites follow the paper's LoRA default: the q and v projections of
every attention block (M = 2*layers), plus a classifier-head site for
sequence classification (the head is trained from scratch through a
'linear' ColA adapter, as in §4.2).

Pallas kernels (interpret=True) from ``kernels/`` are called inline so
they lower into the same HLO: attention + layernorm on the base path,
lora/linear apply on the adapter path.
"""
from __future__ import annotations

import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp

from .kernels import vjp as kv

# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

CONFIGS = {
    # name: vocab, d_model, layers, heads, d_ff, seq
    "tiny": dict(vocab=512, d=128, layers=2, heads=4, dff=512, seq=64),
    "small": dict(vocab=2048, d=256, layers=4, heads=8, dff=1024, seq=128),
    "base": dict(vocab=4096, d=384, layers=8, heads=8, dff=1536, seq=128),
}

RANK = 8          # low-rank adapter rank (paper: r=8)
MLP_HIDDEN = 64   # MLP adapter hidden size (paper: 128; scaled with model)
ADAPTER_SCALE = 1.0  # alpha; GL requires alpha=1 (Sec. 3.2)

# Whether attention/layernorm lower through the Pallas kernels. On the
# CPU-PJRT testbed interpret-mode grid loops cannot fuse and cost ~1.7x
# (EXPERIMENTS.md §Perf), so aot.py lowers the larger sizes with the jnp
# path; adapter apply + worker fit stay Pallas everywhere. On a real TPU
# both paths would be Mosaic-compiled and this switch would stay True.
ATTN_PALLAS = True


def n_sites(cfg) -> int:
    return 2 * cfg["layers"]


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def lm_param_names(cfg):
    """Canonical (ordered) base-weight names — the L3 interface contract."""
    names = ["embed", "pos"]
    for i in range(cfg["layers"]):
        names += [
            f"l{i}.ln1g", f"l{i}.ln1b",
            f"l{i}.wq", f"l{i}.wk", f"l{i}.wv", f"l{i}.wo",
            f"l{i}.ln2g", f"l{i}.ln2b",
            f"l{i}.w1", f"l{i}.b1", f"l{i}.w2", f"l{i}.b2",
        ]
    names += ["lnfg", "lnfb"]
    return names


def lm_param_shapes(cfg):
    v, d, dff, s = cfg["vocab"], cfg["d"], cfg["dff"], cfg["seq"]
    shapes = OrderedDict()
    shapes["embed"] = (v, d)
    shapes["pos"] = (s, d)
    for i in range(cfg["layers"]):
        shapes[f"l{i}.ln1g"] = (d,)
        shapes[f"l{i}.ln1b"] = (d,)
        shapes[f"l{i}.wq"] = (d, d)
        shapes[f"l{i}.wk"] = (d, d)
        shapes[f"l{i}.wv"] = (d, d)
        shapes[f"l{i}.wo"] = (d, d)
        shapes[f"l{i}.ln2g"] = (d,)
        shapes[f"l{i}.ln2b"] = (d,)
        shapes[f"l{i}.w1"] = (d, dff)
        shapes[f"l{i}.b1"] = (dff,)
        shapes[f"l{i}.w2"] = (dff, d)
        shapes[f"l{i}.b2"] = (d,)
    shapes["lnfg"] = (d,)
    shapes["lnfb"] = (d,)
    return shapes


def init_lm_params(cfg, seed: int = 0):
    """Deterministic pretrained-stand-in initialization."""
    shapes = lm_param_shapes(cfg)
    key = jax.random.PRNGKey(seed)
    params = OrderedDict()
    for name, shp in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1g", "ln2g", "lnfg")):
            params[name] = jnp.ones(shp, jnp.float32)
        elif name.endswith(("ln1b", "ln2b", "lnfb", ".b1", ".b2")):
            params[name] = jnp.zeros(shp, jnp.float32)
        else:
            fan_in = shp[0] if len(shp) > 1 else shp[0]
            std = (1.0 / fan_in) ** 0.5
            params[name] = std * jax.random.normal(sub, shp, jnp.float32)
    return params


def adapter_param_shapes(cfg, kind: str):
    """Ordered adapter parameter shapes for all sites of an LM."""
    d = cfg["d"]
    shapes = OrderedDict()
    for i in range(cfg["layers"]):
        for proj in ("q", "v"):
            p = f"l{i}.{proj}"
            if kind == "lowrank":
                shapes[f"{p}.A"] = (d, RANK)
                shapes[f"{p}.B"] = (RANK, d)
            elif kind == "linear":
                shapes[f"{p}.W"] = (d, d)
            elif kind == "mlp":
                shapes[f"{p}.W1"] = (d, MLP_HIDDEN)
                shapes[f"{p}.b1"] = (MLP_HIDDEN,)
                shapes[f"{p}.W2"] = (MLP_HIDDEN, d)
                shapes[f"{p}.b2"] = (d,)
            elif kind == "none":
                pass
            else:
                raise ValueError(kind)
    return shapes


def init_adapter_params(cfg, kind: str, seed: int = 1):
    """Paper init: adapters start at zero output. LoRA-style: A random,
    B zero; linear: zero; MLP: W1 random, W2 zero (so g(x)=b2=0)."""
    shapes = adapter_param_shapes(cfg, kind)
    key = jax.random.PRNGKey(seed)
    out = OrderedDict()
    for name, shp in shapes.items():
        key, sub = jax.random.split(key)
        if name.endswith(".A") or name.endswith(".W1"):
            out[name] = (1.0 / shp[0]) ** 0.5 * jax.random.normal(sub, shp, jnp.float32)
        else:
            out[name] = jnp.zeros(shp, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# adapter application
# ---------------------------------------------------------------------------

def apply_adapter(kind, aps, prefix, x2d, h2d, use_pallas=True):
    """hhat = h + scale * g(x) for one site; x2d,h2d: (n, d)."""
    s = ADAPTER_SCALE
    if kind == "none":
        return h2d
    if kind == "lowrank":
        a, b = aps[f"{prefix}.A"], aps[f"{prefix}.B"]
        if use_pallas:
            return kv.lora_apply(x2d, a, b, h2d, s)
        return h2d + s * (x2d @ a) @ b
    if kind == "linear":
        w = aps[f"{prefix}.W"]
        if use_pallas:
            return kv.linear_apply(x2d, w, h2d, s)
        return h2d + s * x2d @ w
    if kind == "mlp":
        w1, b1 = aps[f"{prefix}.W1"], aps[f"{prefix}.b1"]
        w2, b2 = aps[f"{prefix}.W2"], aps[f"{prefix}.b2"]
        return h2d + s * (jnp.maximum(x2d @ w1 + b1, 0.0) @ w2 + b2)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# transformer forward
# ---------------------------------------------------------------------------

def _mha(q, k, v, heads, causal, use_pallas, kv_prefix=None):
    use_pallas = use_pallas and ATTN_PALLAS
    """q,k,v: (B,S,d) -> (B,S,d). Optional prefix K/V (B,P,d) pairs
    (prefix-tuning baseline) are concatenated before attention."""
    bsz, s, d = q.shape
    dh = d // heads

    def split(t):
        return t.reshape(bsz, t.shape[1], heads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    if kv_prefix is not None:
        pk, pv = kv_prefix  # (B,P,d) each
        kh = jnp.concatenate([split(pk), kh], axis=2)
        vh = jnp.concatenate([split(pv), vh], axis=2)
    if use_pallas and kv_prefix is None:
        att = jax.vmap(jax.vmap(lambda q1, k1, v1: kv.attention(q1, k1, v1, causal)))
        oh = att(qh, kh, vh)
    else:
        skv = kh.shape[2]
        logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(
            jnp.asarray(dh, jnp.float32))
        if causal:
            p = skv - s  # prefix length: always attendable
            row = jnp.arange(s)[:, None]
            col = jnp.arange(skv)[None, :]
            mask = col <= row + p
            logits = jnp.where(mask[None, None], logits, jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(logits, axis=-1)
        oh = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return oh.transpose(0, 2, 1, 3).reshape(bsz, s, d)


def _ln(x3d, g, b, use_pallas):
    use_pallas = use_pallas and ATTN_PALLAS
    bsz, s, d = x3d.shape
    if use_pallas:
        return kv.layernorm(x3d.reshape(-1, d), g, b).reshape(bsz, s, d)
    mu = jnp.mean(x3d, axis=-1, keepdims=True)
    var = jnp.mean((x3d - mu) ** 2, axis=-1, keepdims=True)
    return (x3d - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def lm_forward(params, tokens, cfg, kind="none", adapters=None, eps=None,
               causal=True, use_pallas=True, ia3=None, kv_prefixes=None,
               prompt=None, collect_xs=False):
    """Transformer forward with adapter sites at every q/v projection.

    Returns (hidden (B,S,d) pre-head, xs dict) where xs maps
    ``l{i}.x`` -> hidden input of layer i's adapter sites (the layernormed
    attention input — both q and v adapters read it, like LoRA).

    eps: optional dict ``l{i}.q``/``l{i}.v`` -> (B,S,d) probe added to the
    fine-tuned site output (zeros at runtime; differentiated for grad_hhat).
    ia3: optional dict l{i}.lk/l{i}.lv/l{i}.lff -> scaling vectors (IA3).
    kv_prefixes: optional list per layer of (pk, pv) (B,P,d) prefix K/V.
    prompt: optional (P, d) learnable prompt prepended after embedding
    (prompt-tuning / p-tuning baselines). Loss positions shift accordingly.
    """
    adapters = adapters or {}
    eps = eps or {}
    bsz, s = tokens.shape
    d = cfg["d"]
    h = params["embed"][tokens] + params["pos"][None, :s, :]
    if prompt is not None:
        p = prompt.shape[0]
        h = jnp.concatenate([jnp.broadcast_to(prompt[None], (bsz, p, d)), h], axis=1)
        # pos embeddings only cover seq; prompt carries its own values.
        s = s + p
    xs = {}
    for i in range(cfg["layers"]):
        pre = _ln(h, params[f"l{i}.ln1g"], params[f"l{i}.ln1b"], use_pallas)
        x2d = pre.reshape(-1, d)
        if collect_xs:
            xs[f"l{i}.x"] = pre
        q = (x2d @ params[f"l{i}.wq"]).reshape(bsz, s, d)
        k = (x2d @ params[f"l{i}.wk"]).reshape(bsz, s, d)
        v = (x2d @ params[f"l{i}.wv"]).reshape(bsz, s, d)
        # fine-tuned site outputs: hhat = h + g(x) + eps
        q2 = apply_adapter(kind, adapters, f"l{i}.q", x2d, q.reshape(-1, d),
                           use_pallas).reshape(bsz, s, d)
        v2 = apply_adapter(kind, adapters, f"l{i}.v", x2d, v.reshape(-1, d),
                           use_pallas).reshape(bsz, s, d)
        if f"l{i}.q" in eps:
            q2 = q2 + eps[f"l{i}.q"]
        if f"l{i}.v" in eps:
            v2 = v2 + eps[f"l{i}.v"]
        if ia3 is not None:
            k = k * ia3[f"l{i}.lk"][None, None, :]
            v2 = v2 * ia3[f"l{i}.lv"][None, None, :]
        kvp = kv_prefixes[i] if kv_prefixes is not None else None
        att = _mha(q2, k, v2, cfg["heads"], causal, use_pallas, kv_prefix=kvp)
        h = h + (att.reshape(-1, d) @ params[f"l{i}.wo"]).reshape(bsz, s, d)
        pre2 = _ln(h, params[f"l{i}.ln2g"], params[f"l{i}.ln2b"], use_pallas)
        mid = jnp.maximum(pre2.reshape(-1, d) @ params[f"l{i}.w1"] + params[f"l{i}.b1"], 0.0)
        if ia3 is not None:
            mid = mid * ia3[f"l{i}.lff"][None, :]
        h = h + (mid @ params[f"l{i}.w2"] + params[f"l{i}.b2"]).reshape(bsz, s, d)
    h = _ln(h, params["lnfg"], params["lnfb"], use_pallas)
    return h, xs


def lm_logits(params, hidden):
    """Tied-embedding LM head."""
    bsz, s, d = hidden.shape
    return (hidden.reshape(-1, d) @ params["embed"].T).reshape(bsz, s, -1)


def masked_ce(logits, targets, mask):
    """Mean cross-entropy over mask=1 positions. targets: (B,S) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def masked_token_acc(logits, targets, mask):
    """Teacher-forced token accuracy over mask=1 positions (the
    ROUGE-Longest stand-in for synthetic S2S/CLM tasks)."""
    hit = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32)
    return jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# seq-classification head
# ---------------------------------------------------------------------------

def seqcls_logits(hidden, mask, head_w, eps_head=None):
    """Masked mean-pool + linear head. The base head is identically zero;
    the ColA 'linear' head adapter (head_w) learns the classifier from
    scratch, matching §4.2 ('we use a Linear auxiliary model to train the
    newly initialized classifier layers')."""
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(hidden * mask[..., None], axis=1) / denom  # (B,d)
    out = pooled @ head_w
    if eps_head is not None:
        out = out + eps_head
    return pooled, out


def ce_labels(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


# ---------------------------------------------------------------------------
# decoupled fwd/bwd graph builders (the ColA server artifact)
# ---------------------------------------------------------------------------

def make_lm_fwdbwd(cfg, kind: str, use_pallas: bool = True):
    """Build fn(weights..., adapters..., tokens, targets, mask) ->
    (loss, x_0..x_{L-1}, gq_0..gq_{L-1}, gv_0..gv_{L-1}).

    kind='none' is the merged-mode graph (adapters folded into wq/wv by
    the Rust coordinator; zero adapter inputs).
    Returns (fn, input_names, output_names, input_specs).
    """
    wnames = lm_param_names(cfg)
    wshapes = lm_param_shapes(cfg)
    anames = list(adapter_param_shapes(cfg, kind).keys())
    ashapes = adapter_param_shapes(cfg, kind)
    bsz, s, d = cfg["batch"], cfg["seq"], cfg["d"]
    L = cfg["layers"]

    def fn(*args):
        params = OrderedDict(zip(wnames, args[: len(wnames)]))
        aps = OrderedDict(zip(anames, args[len(wnames): len(wnames) + len(anames)]))
        tokens, targets, mask = args[len(wnames) + len(anames):]

        def inner(eps):
            hidden, xs = lm_forward(params, tokens, cfg, kind=kind,
                                    adapters=aps, eps=eps, causal=True,
                                    use_pallas=use_pallas, collect_xs=True)
            logits = lm_logits(params, hidden)
            loss = masked_ce(logits, targets, mask)
            return loss, (xs, logits)

        eps0 = {f"l{i}.{p}": jnp.zeros((bsz, s, d), jnp.float32)
                for i in range(L) for p in ("q", "v")}
        (loss, (xs, logits)), geps = jax.value_and_grad(inner, has_aux=True)(eps0)
        acc = masked_token_acc(logits, targets, mask)
        outs = [loss, acc]
        outs += [xs[f"l{i}.x"] for i in range(L)]
        outs += [geps[f"l{i}.q"] for i in range(L)]
        outs += [geps[f"l{i}.v"] for i in range(L)]
        return tuple(outs)

    input_names = wnames + anames + ["tokens", "targets", "mask"]
    specs = [jax.ShapeDtypeStruct(wshapes[n], jnp.float32) for n in wnames]
    specs += [jax.ShapeDtypeStruct(ashapes[n], jnp.float32) for n in anames]
    specs += [jax.ShapeDtypeStruct((bsz, s), jnp.int32),
              jax.ShapeDtypeStruct((bsz, s), jnp.int32),
              jax.ShapeDtypeStruct((bsz, s), jnp.float32)]
    output_names = (["loss", "acc"] + [f"l{i}.x" for i in range(L)]
                    + [f"l{i}.gq" for i in range(L)]
                    + [f"l{i}.gv" for i in range(L)])
    return fn, input_names, output_names, specs


def make_lm_fwd(cfg, use_pallas: bool = True):
    """Inference graph (merged weights): fn(weights..., tokens) -> logits."""
    wnames = lm_param_names(cfg)
    wshapes = lm_param_shapes(cfg)
    bsz, s = cfg["batch"], cfg["seq"]

    def fn(*args):
        params = OrderedDict(zip(wnames, args[:-1]))
        tokens = args[-1]
        hidden, _ = lm_forward(params, tokens, cfg, kind="none",
                               causal=True, use_pallas=use_pallas)
        return (lm_logits(params, hidden),)

    input_names = wnames + ["tokens"]
    specs = [jax.ShapeDtypeStruct(wshapes[n], jnp.float32) for n in wnames]
    specs += [jax.ShapeDtypeStruct((bsz, s), jnp.int32)]
    return fn, input_names, ["logits"], specs


def make_seqcls_fwdbwd(cfg, kind: str, n_classes: int, use_pallas: bool = True):
    """Seq-classification decoupled graph. Sites: q/v per layer + head.

    fn(weights..., adapters..., head_w, tokens, labels, mask) ->
    (loss, acc, x_0.., head_x, gq_0.., gv_0.., head_g)
    """
    wnames = lm_param_names(cfg)
    wshapes = lm_param_shapes(cfg)
    anames = list(adapter_param_shapes(cfg, kind).keys())
    ashapes = adapter_param_shapes(cfg, kind)
    bsz, s, d = cfg["batch"], cfg["seq"], cfg["d"]
    L = cfg["layers"]

    def fn(*args):
        params = OrderedDict(zip(wnames, args[: len(wnames)]))
        aps = OrderedDict(zip(anames, args[len(wnames): len(wnames) + len(anames)]))
        head_w, tokens, labels, mask = args[len(wnames) + len(anames):]

        def inner(eps, eps_head):
            hidden, xs = lm_forward(params, tokens, cfg, kind=kind,
                                    adapters=aps, eps=eps, causal=False,
                                    use_pallas=use_pallas, collect_xs=True)
            pooled, logits = seqcls_logits(hidden, mask, head_w, eps_head)
            loss = ce_labels(logits, labels)
            return loss, (xs, pooled, logits)

        eps0 = {f"l{i}.{p}": jnp.zeros((bsz, s, d), jnp.float32)
                for i in range(L) for p in ("q", "v")}
        eph0 = jnp.zeros((bsz, n_classes), jnp.float32)
        (loss, (xs, pooled, logits)), (geps, ghead) = jax.value_and_grad(
            inner, argnums=(0, 1), has_aux=True)(eps0, eph0)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        outs = [loss, acc]
        outs += [xs[f"l{i}.x"] for i in range(L)] + [pooled]
        outs += [geps[f"l{i}.q"] for i in range(L)]
        outs += [geps[f"l{i}.v"] for i in range(L)] + [ghead]
        return tuple(outs)

    input_names = wnames + anames + ["head.W", "tokens", "labels", "mask"]
    specs = [jax.ShapeDtypeStruct(wshapes[n], jnp.float32) for n in wnames]
    specs += [jax.ShapeDtypeStruct(ashapes[n], jnp.float32) for n in anames]
    specs += [jax.ShapeDtypeStruct((d, n_classes), jnp.float32),
              jax.ShapeDtypeStruct((bsz, s), jnp.int32),
              jax.ShapeDtypeStruct((bsz,), jnp.int32),
              jax.ShapeDtypeStruct((bsz, s), jnp.float32)]
    output_names = (["loss", "acc"] + [f"l{i}.x" for i in range(L)] + ["head.x"]
                    + [f"l{i}.gq" for i in range(L)]
                    + [f"l{i}.gv" for i in range(L)] + ["head.g"])
    return fn, input_names, output_names, specs
