//! End-to-end driver: train the `base` transformer (8 layers, d=384,
//! ~16M params) on the synthetic corpus for a few hundred steps through
//! the FULL ColA stack — Pallas kernels inside the AOT'd fwd/bwd
//! artifact, the decoupled server step, interval buffering, gradient
//! offloading to workers, and merged-weight updates — and log the loss
//! curve. Proves all three layers compose on a real training workload.
//!
//!     cargo run --release --example e2e_lm [-- --steps 300 --size base]
//!
//! The curve is written to results/e2e_loss.csv and summarized in
//! EXPERIMENTS.md.

use std::time::Instant;

use cola::cli::Args;
use cola::config::{AdapterKind, Method, Mode, TrainConfig};
use cola::coordinator::Trainer;
use cola::metrics::curves_to_csv;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;

    let mut cfg = TrainConfig::default();
    cfg.task = cola::config::Task::Clm;
    cfg.size = args.get_or("size", "base");
    cfg.dataset = "corpus".into();
    // ColA(Linear, merged): full-rank training from the random init with
    // zero parameter-gradient memory on the server (App. C.3 claim at
    // transformer scale).
    cfg.method = Method::Cola(AdapterKind::Linear);
    cfg.mode = Mode::Merged;
    cfg.steps = args.parse_or("steps", 240usize)?;
    cfg.interval = args.parse_or("interval", 1usize)?;
    // full-rank worker fits are matmul-heavy: run them on the worker's
    // own PJRT device (the paper's offload-to-GPU arm) — §Perf #5
    cfg.offload = cola::config::OffloadTarget::PjrtDevice;
    cfg.workers = args.parse_or("workers", 4usize)?;
    cfg.eval_every = 25;
    cfg.eval_batches = 4;
    cfg.lr = args.parse_or("lr", 2e-3f32)?;
    cfg.async_offload = true; // overlap worker fits with next steps (§3.2)

    println!("e2e: training {} ({} steps, interval {}) on the synthetic corpus",
             cfg.size, cfg.steps, cfg.interval);
    let t0 = Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    println!("setup in {:.1}s; training...", t0.elapsed().as_secs_f64());

    let t1 = Instant::now();
    let report = trainer.run()?;
    let wall = t1.elapsed().as_secs_f64();

    println!("\ntrain loss curve:");
    let n = report.train_loss.points.len();
    for (s, v) in report.train_loss.points.iter().step_by((n / 12).max(1)) {
        println!("  step {s:5}  loss {v:.4}");
    }
    println!("  step {:5}  loss {:.4} (final)",
             report.train_loss.points.last().unwrap().0,
             report.train_loss.last().unwrap());
    println!("\neval loss: {:.4} -> {:.4}",
             report.eval_loss.points.first().map(|(_, v)| *v).unwrap_or(f64::NAN),
             report.eval_loss.last().unwrap_or(f64::NAN));
    println!("eval token acc: {:.1}%", 100.0 * report.eval_acc.tail_mean(2));
    println!("\nwall: {wall:.1}s ({:.3}s/step)", wall / report.timings.steps as f64);
    println!("timings: {}", report.timings.report());
    println!("trainable (full-rank deltas): {}", report.trainable_params);
    println!("server resident: {:.1} MiB",
             report.server_resident_bytes as f64 / (1024.0 * 1024.0));
    println!("worker state:    {:.1} MiB (params+opt moments, off-server)",
             report.worker_state_bytes as f64 / (1024.0 * 1024.0));

    std::fs::create_dir_all("results")?;
    let csv = curves_to_csv(&[&report.train_loss, &report.eval_loss,
                              &report.eval_acc]);
    std::fs::write("results/e2e_loss.csv", csv)?;
    println!("\nloss curve written to results/e2e_loss.csv");

    // sanity: the (frozen-base, q/v full-rank deltas) fine-tune must
    // show a clearly decreasing loss curve on the corpus
    let first = report.train_loss.points[0].1;
    let last = report.train_loss.tail_mean(10);
    anyhow::ensure!(last < first * 0.97,
                    "e2e training did not converge: {first:.3} -> {last:.3}");
    println!("e2e OK: loss {first:.3} -> {last:.3}");
    Ok(())
}
