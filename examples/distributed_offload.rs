//! Distributed gradient offloading over a real loopback socket.
//!
//! Spawns a `cola worker` daemon in-process on an ephemeral port, runs
//! the same tiny ColA config twice — in-process workers vs. TCP offload
//! to the daemon — and verifies the determinism guarantee: **the loss
//! curves are bit-identical**, because the daemon runs the same native
//! kernels and the wire format round-trips every f32 exactly.
//!
//! It then compares the *measured* wire transfer time against what the
//! `TransferModel::cpu_link()` simulation would have charged for the
//! same bytes (the Tables 10-18 sweep model) — see EXPERIMENTS.md
//! §Distributed offload for the recorded numbers.
//!
//! Finally it demonstrates the elastic pool: a fourth run under
//! `failover = "migrate"` has its only daemon KILLED mid-run, a cold
//! standby is promoted, state restores from shadow checkpoints, the
//! lost fits re-dispatch — and the loss curves are still bit-identical.
//! The migration ledger (state bytes moved, stalled intervals, lost
//! fits by name) is printed for EXPERIMENTS.md §Elastic pools.
//!
//! Run: `cargo run --release --example distributed_offload`

use std::sync::Arc;

use cola::config::{AdapterKind, FailoverPolicy, Method, Mode, OffloadTarget,
                   Optimizer, Task, TrainConfig, TransportKind};
use cola::coordinator::{TransferModel, Trainer};
use cola::runtime::Manifest;
use cola::transport::tcp::{request_daemon_shutdown, WorkerDaemon};

fn cfg() -> TrainConfig {
    let mut c = TrainConfig::default();
    c.task = Task::Clm;
    c.size = "tiny".into();
    c.method = Method::Cola(AdapterKind::LowRank);
    c.mode = Mode::Unmerged;
    c.optimizer = Optimizer::Sgd;
    c.steps = 12;
    c.interval = 2;
    c.eval_every = 6;
    c.eval_batches = 2;
    c.lr = 0.05;
    c.seed = 42;
    c.workers = 1;
    c
}

fn main() -> cola::Result<()> {
    let manifest = Arc::new(Manifest::load_or_builtin(std::path::Path::new("artifacts"))?);
    let daemon = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                                    manifest, None)?;
    let addr = daemon.local_addr().to_string();
    println!("worker daemon listening on {addr}");

    println!("\n[1/4] in-process offload (local transport)");
    let mut local = Trainer::new(cfg())?;
    let r_local = local.run()?;
    drop(local);

    println!("[2/4] TCP offload to the loopback daemon (one Fit frame per job)");
    let mut over_tcp = cfg();
    over_tcp.offload_transport = TransportKind::Tcp;
    over_tcp.worker_addrs = vec![addr.clone()];
    let mut tcp = Trainer::new(over_tcp.clone())?;
    let r_tcp = tcp.run()?;
    drop(tcp); // release the connection before the shutdown handshake

    println!("[3/4] batched + pipelined TCP (FitBatch frames, window 2)");
    let mut over_batch = over_tcp.clone();
    over_batch.offload_batch = true;
    over_batch.offload_inflight = 2;
    let mut batched = Trainer::new(over_batch)?;
    let r_batched = batched.run()?;
    drop(batched);

    println!("[4/4] failover = migrate: kill the daemon mid-run, promote a standby");
    let mut victim = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                                        Arc::new(Manifest::load_or_builtin(
                                            std::path::Path::new("artifacts"))?),
                                        None)?;
    let standby = WorkerDaemon::bind("127.0.0.1:0", OffloadTarget::NativeCpu,
                                     Arc::new(Manifest::load_or_builtin(
                                         std::path::Path::new("artifacts"))?),
                                     None)?;
    let standby_addr = standby.local_addr().to_string();
    let mut chaos = over_tcp;
    chaos.worker_addrs = vec![victim.local_addr().to_string()];
    chaos.standby_addrs = vec![standby_addr.clone()];
    chaos.failover = FailoverPolicy::Migrate;
    chaos.heartbeat_interval = 0; // reactive: show the lost fits by name
    let mut survivor_run = Trainer::new(chaos)?;
    let r_chaos = survivor_run.run_with_hook(|_, t| {
        if t == 5 {
            // between steps, with an interval of fits about to flush:
            // the harshest spot short of mid-wire
            victim.kill();
        }
        Ok(())
    })?;
    let lost: Vec<String> = survivor_run
        .lost_fits()
        .iter()
        .map(|(u, s)| format!("(user {u}, site {s})"))
        .collect();
    drop(survivor_run);

    for (name, r) in
        [("tcp", &r_tcp), ("tcp+batch", &r_batched), ("tcp+failover", &r_chaos)]
    {
        assert_eq!(r_local.train_loss.points, r.train_loss.points,
                   "determinism violation: {name} train curves differ");
        assert_eq!(r_local.eval_loss.points, r.eval_loss.points,
                   "determinism violation: {name} eval curves differ");
    }
    println!("\ndeterminism: train + eval loss curves are bit-identical \
              across all four dispatch shapes — including the run whose \
              only daemon was killed mid-training ✓");
    println!("\nfailover ledger (the migration cost of surviving the kill):");
    println!("  lost fits (re-dispatched) : {}", lost.len());
    for l in &lost {
        println!("    {l}");
    }
    println!("  migrations                : {}", r_chaos.timings.migrations);
    println!("  state bytes moved         : {}", r_chaos.timings.migrated_state_bytes);
    println!("  stalled intervals         : {}", r_chaos.timings.stall_intervals);
    println!("  final train loss: {:.6}",
             r_tcp.train_loss.last().unwrap_or(f64::NAN));
    println!("\nfit dispatch round-trips (the cost FitBatch collapses):");
    println!("  per-job Fit frames : {}", r_tcp.timings.round_trips);
    println!("  FitBatch, window 2 : {}", r_batched.timings.round_trips);

    // measured wire vs. the simulated link the sweeps use
    let bytes = r_tcp.timings.bytes_offloaded + r_tcp.timings.bytes_returned;
    let simulated: f64 = TransferModel::cpu_link()
        .delay_for(bytes as usize)
        .as_secs_f64();
    println!("\ntransfer accounting over {} training steps:", r_tcp.timings.steps);
    println!("  payload bytes (out + back) : {bytes}");
    println!("  measured loopback transfer : {:.4}s total ({:.6}s/step)",
             r_tcp.timings.transfer.as_secs_f64(),
             r_tcp.timings.per_step(r_tcp.timings.transfer));
    println!("  TransferModel::cpu_link()  : {:.4}s for the same bytes \
              (one-shot; per-job latency adds more)", simulated);
    println!("  (loopback has no physical link — the gap between these \
              numbers is the wire-format + syscall overhead the \
              simulation ignores)");

    request_daemon_shutdown(&addr)?;
    daemon.join();
    request_daemon_shutdown(&standby_addr)?;
    standby.join();
    let _ = victim; // killed mid-run; nothing left to stop
    println!("\nworker daemons shut down cleanly");
    Ok(())
}
