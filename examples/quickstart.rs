//! Quickstart: fine-tune a pretrained-stand-in transformer on a synthetic
//! instruction-tuning task with ColA (Gradient Learning).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What happens each step (Algorithm 1):
//!   server device: fwd+bwd of the base model -> loss + (x_m, grad_hhat_m)
//!   worker device: surrogate fit (Prop. 1) -> adapter update
//! and no parameter gradient is ever computed on the server.

use cola::config::{AdapterKind, Method, Mode, TrainConfig};
use cola::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.task = cola::config::Task::Clm;
    cfg.size = "tiny".into();
    cfg.method = Method::Cola(AdapterKind::LowRank);
    cfg.mode = Mode::Merged; // server memory independent of adapter size
    cfg.steps = 120;
    cfg.interval = 2; // buffer 2 batches per adapter update
    cfg.eval_every = 30;
    cfg.eval_batches = 4;

    println!("ColA quickstart: {} / {} / merged, {} steps",
             cfg.size, cfg.method, cfg.steps);
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;

    println!("\nloss curve (train):");
    for (s, v) in report.train_loss.points.iter().step_by(20) {
        println!("  step {s:4}  loss {v:.4}");
    }
    println!("\neval loss:");
    for (s, v) in &report.eval_loss.points {
        println!("  step {s:4}  loss {v:.4}");
    }
    println!("\nfinal score (teacher-forced token acc x100): {:.1}",
             report.score());
    println!("trainable adapter params: {}", report.trainable_params);
    println!("server resident: {:.1} MiB  (independent of adapter size in merged mode)",
             report.server_resident_bytes as f64 / (1024.0 * 1024.0));
    println!("timings: {}", report.timings.report());
    Ok(())
}
