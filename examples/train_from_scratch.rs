//! Learning from scratch (Appendix C.3): ColA(Linear, merged) trains a
//! network from its random init *without any low-rank approximation*
//! while keeping the server free of parameter gradients — and matches
//! full FT, while LoRA's rank bottleneck costs accuracy.
//!
//!     cargo run --release --example train_from_scratch [-- mlp smnist]

use cola::config::{AdapterKind, Method, Mode, Optimizer, TrainConfig};
use cola::coordinator::{Driver, Trainer};
use cola::runtime::Runtime;

fn run(model: &str, set: &str, method: Method, mode: Mode, steps: usize)
       -> anyhow::Result<(f64, usize)> {
    let rt = Runtime::load("artifacts")?;
    let driver = Driver::new_ic(model, set, 32, 7)?;
    let mut cfg = TrainConfig::default();
    cfg.method = method;
    cfg.mode = mode;
    cfg.steps = steps;
    cfg.batch = 32;
    cfg.lr = 0.05;
    cfg.optimizer = Optimizer::Sgd;
    cfg.eval_every = 0;
    cfg.eval_batches = 8;
    let mut t = Trainer::with_driver(cfg, rt, driver)?;
    let r = t.run()?;
    Ok((100.0 * r.eval_acc.tail_mean(1), r.trainable_params))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("mlp").to_string();
    let set = args.get(1).map(String::as_str).unwrap_or("smnist").to_string();
    let steps = 150;

    println!("from-scratch study: model={model}, set={set}, {steps} steps\n");
    let arms: Vec<(&str, Method, Mode)> = vec![
        ("FT (coupled)", Method::Ft, Mode::Unmerged),
        ("LoRA (coupled)", Method::Lora, Mode::Unmerged),
        ("ColA (LowRank, merged)", Method::Cola(AdapterKind::LowRank), Mode::Merged),
        ("ColA (Linear, merged)", Method::Cola(AdapterKind::Linear), Mode::Merged),
        ("ColA (MLP, unmerged)", Method::Cola(AdapterKind::Mlp), Mode::Unmerged),
    ];
    println!("{:28} {:>10} {:>12}", "method", "acc", "trainable");
    for (label, method, mode) in arms {
        let (acc, params) = run(&model, &set, method, mode, steps)?;
        println!("{label:28} {acc:9.1}% {params:12}");
    }
    println!("\nexpected shape (paper Table 9): ColA(Linear) ≈ FT > LoRA ≈ ColA(LowRank)");
    Ok(())
}
