//! FTaaS collaboration (Figure 1 / Table 4): K users fine-tune the same
//! hosted base model on their own data categories. Adapters are merged
//! into the base during training, so the server's footprint does not
//! grow with K; each user's gradient computation runs on low-cost
//! worker devices; users can download their adapters at any time.
//!
//!     cargo run --release --example ftaas_collaboration

use cola::config::{AdapterKind, TrainConfig};
use cola::coordinator::FtaasService;
use cola::data::lm::CATEGORIES;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.size = "tiny".into();
    cfg.users = 4;
    cfg.batch = 8; // 2 examples/user/step
    cfg.workers = 4;
    cfg.interval = 2;
    cfg.steps = 0; // driven below
    cfg.eval_batches = 4;

    println!("starting FTaaS service with {} collaborating users", cfg.users);
    let mut svc = FtaasService::start(cfg, AdapterKind::LowRank)?;
    for j in svc.jobs() {
        println!("  user {} fine-tunes on '{}'", j.user, CATEGORIES[j.category]);
    }

    let baseline: Vec<f64> = (0..4)
        .map(|c| svc.category_score(c))
        .collect::<anyhow::Result<_>>()?;

    for round in 0..6 {
        svc.run_rounds(20)?;
        let st = svc.status()?;
        println!("after {:3} rounds: train loss {:.4}, server {:.1} MiB",
                 (round + 1) * 20,
                 st.last_train_loss.unwrap_or(f64::NAN),
                 st.server_resident_bytes as f64 / (1024.0 * 1024.0));
    }

    println!("\nper-category quality (before -> after collaboration):");
    for c in 0..4 {
        let after = svc.category_score(c)?;
        println!("  {:24} {:5.1} -> {:5.1}", CATEGORIES[c], baseline[c], after);
    }

    // each user downloads their trained adapter (Figure 1 local path)
    println!("\nadapter downloads:");
    for u in 0..4 {
        let p = svc.fetch_adapter(u, "l0.q")?;
        println!("  user {u}: site l0.q, {} params, ||delta|| = {:.4}",
                 p.n_params(),
                 cola::tensor::norm(&p.delta_matrix()?));
    }
    Ok(())
}
