#!/usr/bin/env bash
# Distributed-offload smoke: launch a `cola worker` daemon on an
# ephemeral loopback port, train the same tiny config in-process and
# over TCP, and require byte-identical loss curves. Used by the
# `distributed-smoke` CI job; runnable locally after
# `cargo build --release --locked`.
set -euo pipefail

BIN=${BIN:-./target/release/cola}
OUT=$(mktemp -d)

cleanup() {
  # belt and braces: never leave a daemon behind, even on failure paths
  if [ -n "${WORKER_PID:-}" ] && kill -0 "$WORKER_PID" 2>/dev/null; then
    kill "$WORKER_PID" 2>/dev/null || true
  fi
}
trap cleanup EXIT

"$BIN" worker --listen 127.0.0.1:0 --threads 2 >"$OUT/worker.log" 2>&1 &
WORKER_PID=$!

# scrape the resolved port from the daemon's startup line
ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$OUT/worker.log" | head -n1)
  [ -n "$ADDR" ] && break
  if ! kill -0 "$WORKER_PID" 2>/dev/null; then
    echo "FAIL: worker daemon died during startup" >&2
    cat "$OUT/worker.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [ -z "$ADDR" ]; then
  echo "FAIL: worker daemon never reported its address" >&2
  cat "$OUT/worker.log" >&2
  exit 1
fi
echo "worker daemon at $ADDR (pid $WORKER_PID)"

echo "--- in-process run"
"$BIN" train --config config/distributed_smoke.toml \
  --loss_out "$OUT/local.json"

echo "--- loopback-TCP run"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --loss_out "$OUT/tcp.json"

if ! kill -0 "$WORKER_PID" 2>/dev/null; then
  echo "FAIL: worker daemon crashed during training" >&2
  cat "$OUT/worker.log" >&2
  exit 1
fi

if ! diff "$OUT/local.json" "$OUT/tcp.json"; then
  echo "FAIL: TCP loss curves differ from the in-process run" >&2
  echo "--- worker log:" >&2
  cat "$OUT/worker.log" >&2
  exit 1
fi
echo "OK: loss curves are byte-identical across transports"

# clean shutdown handshake; the daemon must exit 0
"$BIN" worker --stop "$ADDR"
wait "$WORKER_PID"
echo "OK: worker daemon exited cleanly after the shutdown handshake"
WORKER_PID=""
