#!/usr/bin/env bash
# Distributed-offload smoke: launch `cola worker` daemons on ephemeral
# loopback ports and require byte-identical loss curves across every
# dispatch shape:
#
#   1. in-process workers vs loopback TCP (the original contract);
#   2. batched + pipelined TCP (--offload_batch true --offload_inflight 2,
#      wire-v2 FitBatch frames) vs the same baseline;
#   3. TWO trainers running CONCURRENTLY against the one daemon
#      (multi-tenant: --offload_tenant u0/u1) vs their dedicated
#      in-process baselines;
#   4. CHAOS: one of two daemons is kill -9'd mid-run with
#      --failover migrate and a --standby_addrs spare — the standby is
#      promoted, state restores from shadow checkpoints, and the loss
#      curves STILL byte-diff clean against the uninterrupted run.
#   5. WIRE: the same TCP run with --offload_wire f32 vs bf16 — bf16
#      must train within `cola curvediff --tol 0.05` of the f32 curves
#      AND put >= 40% fewer request bytes on the wire (scraped from the
#      greppable `wire bytes N` timings field).
#   6. REGISTRY: the coordinator opens a --registry_listen announce
#      port, two fresh daemons self-register with `cola worker --join`,
#      one of them is kill -9'd mid-run with --replicate true — the run
#      must finish with ZERO lost fits, zero stalled intervals, and
#      loss curves byte-identical to the uninterrupted baseline (buddy
#      replicas promote in place; no recovery round).
#
# Usage: distributed_smoke.sh [all|basic|chaos|wire|registry]  (default: all)
# CI runs `basic`, `chaos`, `wire`, and `registry` as separate steps
# with their own timeout-minutes. Runnable locally after
# `cargo build --release --locked`.
set -euo pipefail

BIN=${BIN:-./target/release/cola}
OUT=$(mktemp -d)
MODE="${1:-all}"
case "$MODE" in all|basic|chaos|wire|registry) ;; *)
  echo "usage: $0 [all|basic|chaos|wire|registry]" >&2; exit 2 ;;
esac

cleanup() {
  # belt and braces: never leave a daemon behind, even on failure paths
  for pid in "${WORKER_PID:-}" "${WORKER2_PID:-}" "${WORKER3_PID:-}" \
             "${JOINER1_PID:-}" "${JOINER2_PID:-}"; do
    if [ -n "$pid" ] && kill -0 "$pid" 2>/dev/null; then
      kill "$pid" 2>/dev/null || true
    fi
  done
}
trap cleanup EXIT

# launch a daemon, scrape its resolved ephemeral port from the startup
# line: start_worker <logfile> [join_addr]; sets SPAWNED_PID and
# SPAWNED_ADDR. With a join_addr the daemon self-registers against a
# coordinator's --registry_listen announce port.
start_worker() {
  if [ -n "${2:-}" ]; then
    "$BIN" worker --listen 127.0.0.1:0 --threads 2 --join "$2" >"$1" 2>&1 &
  else
    "$BIN" worker --listen 127.0.0.1:0 --threads 2 >"$1" 2>&1 &
  fi
  SPAWNED_PID=$!
  SPAWNED_ADDR=""
  for _ in $(seq 1 100); do
    SPAWNED_ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$1" | head -n1)
    [ -n "$SPAWNED_ADDR" ] && break
    if ! kill -0 "$SPAWNED_PID" 2>/dev/null; then
      echo "FAIL: worker daemon died during startup" >&2
      cat "$1" >&2
      exit 1
    fi
    sleep 0.1
  done
  if [ -z "$SPAWNED_ADDR" ]; then
    echo "FAIL: worker daemon never reported its address" >&2
    cat "$1" >&2
    exit 1
  fi
}

start_worker "$OUT/worker.log"
WORKER_PID=$SPAWNED_PID
ADDR=$SPAWNED_ADDR
echo "worker daemon at $ADDR (pid $WORKER_PID)"

require_daemon_alive() {
  if ! kill -0 "$WORKER_PID" 2>/dev/null; then
    echo "FAIL: worker daemon crashed ($1)" >&2
    cat "$OUT/worker.log" >&2
    exit 1
  fi
}

require_identical() {
  if ! diff "$2" "$3"; then
    echo "FAIL: $1 loss curves differ" >&2
    echo "--- worker log:" >&2
    cat "$OUT/worker.log" >&2
    exit 1
  fi
  echo "OK: $1 loss curves are byte-identical"
}

if [ "$MODE" = "all" ] || [ "$MODE" = "basic" ]; then

echo "--- in-process run"
"$BIN" train --config config/distributed_smoke.toml \
  --loss_out "$OUT/local.json"

echo "--- loopback-TCP run (v1 wire: one Fit frame per job)"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --loss_out "$OUT/tcp.json"
require_daemon_alive "during the unbatched TCP run"
require_identical "TCP vs in-process" "$OUT/local.json" "$OUT/tcp.json"

echo "--- batched + pipelined TCP run (wire-v2 FitBatch, window 2)"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --offload_batch true --offload_inflight 2 \
  --loss_out "$OUT/tcp_batched.json"
require_daemon_alive "during the batched TCP run"
require_identical "batched TCP vs in-process" "$OUT/local.json" "$OUT/tcp_batched.json"

echo "--- second in-process baseline (seed 43) for the shared-daemon pair"
"$BIN" train --config config/distributed_smoke.toml --seed 43 \
  --loss_out "$OUT/local_b.json"

echo "--- TWO concurrent trainers sharing the one daemon (tenants u0/u1)"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" --offload_tenant u0 \
  --loss_out "$OUT/shared_a.json" >"$OUT/shared_a.log" 2>&1 &
PID_A=$!
"$BIN" train --config config/distributed_smoke.toml --seed 43 \
  --offload_transport tcp --worker_addrs "$ADDR" --offload_tenant u1 \
  --offload_batch true --offload_inflight 2 \
  --loss_out "$OUT/shared_b.json" >"$OUT/shared_b.log" 2>&1 &
PID_B=$!
for pid in "$PID_A" "$PID_B"; do
  if ! wait "$pid"; then
    echo "FAIL: a shared-daemon trainer (pid $pid) exited non-zero" >&2
    echo "--- trainer A log:" >&2; cat "$OUT/shared_a.log" >&2
    echo "--- trainer B log:" >&2; cat "$OUT/shared_b.log" >&2
    echo "--- worker log:" >&2; cat "$OUT/worker.log" >&2
    exit 1
  fi
done
require_daemon_alive "during the shared-daemon runs"
require_identical "shared-daemon trainer A vs its baseline" \
  "$OUT/local.json" "$OUT/shared_a.json"
require_identical "shared-daemon trainer B vs its baseline" \
  "$OUT/local_b.json" "$OUT/shared_b.json"

fi # basic shapes

if [ "$MODE" = "all" ] || [ "$MODE" = "wire" ]; then

echo "--- wire shape: f32 vs bf16 fit tensors over the same daemon"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --offload_batch true --offload_wire f32 \
  --loss_out "$OUT/wire_f32.json" | tee "$OUT/wire_f32.log"
require_daemon_alive "during the f32 wire run"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --offload_batch true --offload_wire bf16 \
  --loss_out "$OUT/wire_bf16.json" | tee "$OUT/wire_bf16.log"
require_daemon_alive "during the bf16 wire run"

# bf16 truncation is deterministic but not bit-identical to f32 — the
# contract is a bounded drift (documented tolerance 0.05 relative)
if ! "$BIN" curvediff "$OUT/wire_f32.json" "$OUT/wire_bf16.json" --tol 0.05; then
  echo "FAIL: bf16 wire curves drifted past tol 0.05 of the f32 run" >&2
  echo "--- worker log:" >&2
  cat "$OUT/worker.log" >&2
  exit 1
fi
echo "OK: bf16 loss curves are within tol 0.05 of f32"

# the timings line prints the drained request-byte ledger exactly:
# "... | wire bytes N"
scrape_wire_bytes() {
  sed -n 's/.*| wire bytes \([0-9][0-9]*\).*/\1/p' "$1" | head -n1
}
F32_BYTES=$(scrape_wire_bytes "$OUT/wire_f32.log")
BF16_BYTES=$(scrape_wire_bytes "$OUT/wire_bf16.log")
if [ -z "$F32_BYTES" ] || [ -z "$BF16_BYTES" ]; then
  echo "FAIL: could not scrape 'wire bytes' from the train output" >&2
  exit 1
fi
REDUCTION=$(awk -v a="$F32_BYTES" -v b="$BF16_BYTES" \
  'BEGIN { printf "%.1f", 100.0 * (1.0 - b / a) }')
echo "wire bytes: f32 $F32_BYTES -> bf16 $BF16_BYTES (${REDUCTION}% reduction)"
MIN_SAVING="${COLA_SMOKE_MIN_WIRE_SAVING:-40}"
if ! awk -v r="$REDUCTION" -v m="$MIN_SAVING" 'BEGIN { exit !(r >= m) }'; then
  echo "FAIL: bf16 reduced wire bytes by ${REDUCTION}%, need >= ${MIN_SAVING}%" >&2
  exit 1
fi
echo "OK: bf16 cut request wire bytes by ${REDUCTION}% (>= ${MIN_SAVING}%)"

fi # wire shape

if [ "$MODE" = "all" ] || [ "$MODE" = "chaos" ]; then

echo "--- chaos shape: kill one of two daemons mid-run, promote a standby"
start_worker "$OUT/worker2.log"
WORKER2_PID=$SPAWNED_PID
ADDR2=$SPAWNED_ADDR
start_worker "$OUT/worker3.log"
WORKER3_PID=$SPAWNED_PID
ADDR3=$SPAWNED_ADDR
echo "second daemon at $ADDR2 (pid $WORKER2_PID), standby at $ADDR3 (pid $WORKER3_PID)"

# longer run so the kill lands mid-training; its own clean baseline
CHAOS_STEPS=32
"$BIN" train --config config/distributed_smoke.toml --steps "$CHAOS_STEPS" \
  --loss_out "$OUT/chaos_base.json"

"$BIN" train --config config/distributed_smoke.toml --steps "$CHAOS_STEPS" \
  --offload_transport tcp --worker_addrs "$ADDR,$ADDR2" \
  --standby_addrs "$ADDR3" --failover migrate --heartbeat_interval 1 \
  --offload_batch true --offload_inflight 2 \
  --offload_tenant chaos \
  --loss_out "$OUT/chaos.json" >"$OUT/chaos.log" 2>&1 &
TRAIN_PID=$!
sleep 1
if kill -9 "$WORKER2_PID" 2>/dev/null; then
  echo "killed daemon $ADDR2 (pid $WORKER2_PID) mid-run"
else
  echo "NOTE: daemon 2 already gone before the kill"
fi
WORKER2_PID=""
if ! wait "$TRAIN_PID"; then
  echo "FAIL: the chaos-run trainer exited non-zero" >&2
  echo "--- trainer log:" >&2; cat "$OUT/chaos.log" >&2
  echo "--- worker 1 log:" >&2; cat "$OUT/worker.log" >&2
  echo "--- standby log:" >&2; cat "$OUT/worker3.log" >&2
  exit 1
fi
require_daemon_alive "during the chaos run (daemon 1 must survive)"
require_identical "chaos run (daemon killed mid-run) vs clean" \
  "$OUT/chaos_base.json" "$OUT/chaos.json"
if grep -q "promoted standby" "$OUT/chaos.log"; then
  echo "OK: standby was promoted mid-run"
else
  # the kill may have landed after training finished on a fast machine;
  # curves were still verified identical above
  echo "NOTE: kill landed too late to trigger a failover (run already done)"
fi

# the standby daemon must still shut down cleanly
"$BIN" worker --stop "$ADDR3"
wait "$WORKER3_PID"
WORKER3_PID=""
echo "OK: standby daemon exited cleanly"

fi # chaos shape

if [ "$MODE" = "all" ] || [ "$MODE" = "registry" ]; then

echo "--- registry shape: daemons self-register via --join, one is kill -9'd"
REG_STEPS=32
REG_USERS=4
# uninterrupted baseline: membership and placement never move a curve,
# so the reference is the plain in-process run of the same config.
# --mode merged: multi-user training in one server requires it (and
# merged delta adds are the hardest determinism shape anyway)
"$BIN" train --config config/distributed_smoke.toml --steps "$REG_STEPS" \
  --users "$REG_USERS" --mode merged \
  --loss_out "$OUT/registry_base.json"

# coordinator first: the static daemon bootstraps the pool while the
# registry listener accepts `--join` self-registrations on an
# ephemeral port; buddy replication makes the later kill free
"$BIN" train --config config/distributed_smoke.toml --steps "$REG_STEPS" \
  --users "$REG_USERS" --mode merged \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --registry_listen 127.0.0.1:0 --failover migrate --heartbeat_interval 1 \
  --replicate true --offload_batch true --offload_inflight 2 \
  --offload_tenant registry \
  --loss_out "$OUT/registry.json" >"$OUT/registry.log" 2>&1 &
TRAIN_PID=$!

# scrape the announce address from the trainer's greppable startup line
REG_ADDR=""
for _ in $(seq 1 100); do
  REG_ADDR=$(sed -n 's/.*worker registry listening on \([0-9.]*:[0-9]*\).*/\1/p' \
    "$OUT/registry.log" | head -n1)
  [ -n "$REG_ADDR" ] && break
  if ! kill -0 "$TRAIN_PID" 2>/dev/null; then
    echo "FAIL: the registry-run trainer died before announcing its registry" >&2
    cat "$OUT/registry.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [ -z "$REG_ADDR" ]; then
  echo "FAIL: trainer never announced a registry address" >&2
  cat "$OUT/registry.log" >&2
  exit 1
fi
echo "registry listening at $REG_ADDR"

start_worker "$OUT/joiner1.log" "$REG_ADDR"
JOINER1_PID=$SPAWNED_PID
JOINER1_ADDR=$SPAWNED_ADDR
start_worker "$OUT/joiner2.log" "$REG_ADDR"
JOINER2_PID=$SPAWNED_PID
JOINER2_ADDR=$SPAWNED_ADDR
echo "joiners at $JOINER1_ADDR (pid $JOINER1_PID), $JOINER2_ADDR (pid $JOINER2_PID)"

sleep 1
if kill -9 "$JOINER2_PID" 2>/dev/null; then
  echo "killed joiner $JOINER2_ADDR (pid $JOINER2_PID) mid-run"
else
  echo "NOTE: joiner 2 already gone before the kill"
fi
JOINER2_PID=""

if ! wait "$TRAIN_PID"; then
  echo "FAIL: the registry-run trainer exited non-zero" >&2
  echo "--- trainer log:" >&2; cat "$OUT/registry.log" >&2
  echo "--- static worker log:" >&2; cat "$OUT/worker.log" >&2
  echo "--- joiner 1 log:" >&2; cat "$OUT/joiner1.log" >&2
  echo "--- joiner 2 log:" >&2; cat "$OUT/joiner2.log" >&2
  exit 1
fi
require_daemon_alive "during the registry run (the static daemon must survive)"
require_identical "registry run (joiner killed mid-run) vs clean" \
  "$OUT/registry_base.json" "$OUT/registry.json"

# the join handshake itself must have worked, not just the static member
if ! grep -q "registered with coordinator at" "$OUT/joiner1.log"; then
  echo "FAIL: joiner 1 never registered with the coordinator" >&2
  cat "$OUT/joiner1.log" >&2
  exit 1
fi
echo "OK: daemons self-registered via --join"

# a kill absorbed by buddy promotion must cost NOTHING: the timings
# ledger may not report a single lost fit or stalled interval
if grep -Eq "lost fits recovered [1-9]|stalled intervals [1-9]" "$OUT/registry.log"; then
  echo "FAIL: the registry run recovered lost fits or stalled — the kill was not free" >&2
  cat "$OUT/registry.log" >&2
  exit 1
fi
echo "OK: zero lost fits, zero stalled intervals"
if grep -q "| shards promoted " "$OUT/registry.log"; then
  echo "OK: buddy replicas were promoted in place of checkpoint restores"
else
  # the kill may have landed after training finished on a fast machine,
  # or hit a member owning no shards; curves were verified above
  echo "NOTE: kill landed too late (or hit an empty member) — no promotions"
fi

# the surviving joiner must still shut down cleanly
"$BIN" worker --stop "$JOINER1_ADDR"
wait "$JOINER1_PID"
JOINER1_PID=""
echo "OK: surviving joiner exited cleanly"

fi # registry shape

# clean shutdown handshake; the daemon must exit 0
"$BIN" worker --stop "$ADDR"
wait "$WORKER_PID"
echo "OK: worker daemon exited cleanly after the shutdown handshake"
WORKER_PID=""
