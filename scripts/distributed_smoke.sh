#!/usr/bin/env bash
# Distributed-offload smoke: launch ONE `cola worker` daemon on an
# ephemeral loopback port and require byte-identical loss curves across
# every dispatch shape:
#
#   1. in-process workers vs loopback TCP (the original contract);
#   2. batched + pipelined TCP (--offload_batch true --offload_inflight 2,
#      wire-v2 FitBatch frames) vs the same baseline;
#   3. TWO trainers running CONCURRENTLY against the one daemon
#      (multi-tenant: --offload_tenant u0/u1) vs their dedicated
#      in-process baselines.
#
# Used by the `distributed-smoke` CI job; runnable locally after
# `cargo build --release --locked`.
set -euo pipefail

BIN=${BIN:-./target/release/cola}
OUT=$(mktemp -d)

cleanup() {
  # belt and braces: never leave a daemon behind, even on failure paths
  if [ -n "${WORKER_PID:-}" ] && kill -0 "$WORKER_PID" 2>/dev/null; then
    kill "$WORKER_PID" 2>/dev/null || true
  fi
}
trap cleanup EXIT

"$BIN" worker --listen 127.0.0.1:0 --threads 2 >"$OUT/worker.log" 2>&1 &
WORKER_PID=$!

# scrape the resolved port from the daemon's startup line
ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$OUT/worker.log" | head -n1)
  [ -n "$ADDR" ] && break
  if ! kill -0 "$WORKER_PID" 2>/dev/null; then
    echo "FAIL: worker daemon died during startup" >&2
    cat "$OUT/worker.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [ -z "$ADDR" ]; then
  echo "FAIL: worker daemon never reported its address" >&2
  cat "$OUT/worker.log" >&2
  exit 1
fi
echo "worker daemon at $ADDR (pid $WORKER_PID)"

require_daemon_alive() {
  if ! kill -0 "$WORKER_PID" 2>/dev/null; then
    echo "FAIL: worker daemon crashed ($1)" >&2
    cat "$OUT/worker.log" >&2
    exit 1
  fi
}

require_identical() {
  if ! diff "$2" "$3"; then
    echo "FAIL: $1 loss curves differ" >&2
    echo "--- worker log:" >&2
    cat "$OUT/worker.log" >&2
    exit 1
  fi
  echo "OK: $1 loss curves are byte-identical"
}

echo "--- in-process run"
"$BIN" train --config config/distributed_smoke.toml \
  --loss_out "$OUT/local.json"

echo "--- loopback-TCP run (v1 wire: one Fit frame per job)"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --loss_out "$OUT/tcp.json"
require_daemon_alive "during the unbatched TCP run"
require_identical "TCP vs in-process" "$OUT/local.json" "$OUT/tcp.json"

echo "--- batched + pipelined TCP run (wire-v2 FitBatch, window 2)"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" \
  --offload_batch true --offload_inflight 2 \
  --loss_out "$OUT/tcp_batched.json"
require_daemon_alive "during the batched TCP run"
require_identical "batched TCP vs in-process" "$OUT/local.json" "$OUT/tcp_batched.json"

echo "--- second in-process baseline (seed 43) for the shared-daemon pair"
"$BIN" train --config config/distributed_smoke.toml --seed 43 \
  --loss_out "$OUT/local_b.json"

echo "--- TWO concurrent trainers sharing the one daemon (tenants u0/u1)"
"$BIN" train --config config/distributed_smoke.toml \
  --offload_transport tcp --worker_addrs "$ADDR" --offload_tenant u0 \
  --loss_out "$OUT/shared_a.json" >"$OUT/shared_a.log" 2>&1 &
PID_A=$!
"$BIN" train --config config/distributed_smoke.toml --seed 43 \
  --offload_transport tcp --worker_addrs "$ADDR" --offload_tenant u1 \
  --offload_batch true --offload_inflight 2 \
  --loss_out "$OUT/shared_b.json" >"$OUT/shared_b.log" 2>&1 &
PID_B=$!
for pid in "$PID_A" "$PID_B"; do
  if ! wait "$pid"; then
    echo "FAIL: a shared-daemon trainer (pid $pid) exited non-zero" >&2
    echo "--- trainer A log:" >&2; cat "$OUT/shared_a.log" >&2
    echo "--- trainer B log:" >&2; cat "$OUT/shared_b.log" >&2
    echo "--- worker log:" >&2; cat "$OUT/worker.log" >&2
    exit 1
  fi
done
require_daemon_alive "during the shared-daemon runs"
require_identical "shared-daemon trainer A vs its baseline" \
  "$OUT/local.json" "$OUT/shared_a.json"
require_identical "shared-daemon trainer B vs its baseline" \
  "$OUT/local_b.json" "$OUT/shared_b.json"

# clean shutdown handshake; the daemon must exit 0
"$BIN" worker --stop "$ADDR"
wait "$WORKER_PID"
echo "OK: worker daemon exited cleanly after the shutdown handshake"
WORKER_PID=""
