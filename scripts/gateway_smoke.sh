#!/usr/bin/env bash
# FTaaS gateway smoke: launch `cola serve` on an ephemeral loopback
# port and require the full HTTP surface to hold its contracts:
#
#   1. DETERMINISM: a job submitted over POST /v1/fit must produce loss
#      curves and an adapter bundle byte-identical to the same config
#      run via `cola train --loss_out --adapter_out`;
#   2. AUTH: a wrong bearer token answers 401, /healthz needs none;
#   3. STREAMING: GET /v1/jobs/{id}/progress follows the run live as
#      chunked JSONL and closes with a terminal {"done":true} line;
#   4. LEDGER: the fire-and-forget usage ledger lands per-interval
#      per-user JSONL rows attributed to the submitting tenant;
#   5. SHUTDOWN: POST /v1/shutdown exits the server process cleanly.
#
# The client side is `cola http` (stdlib-only) — CI runners need no
# curl. Runnable locally after `cargo build --release --locked`.
set -euo pipefail

BIN=${BIN:-./target/release/cola}
OUT=$(mktemp -d)

cleanup() {
  # belt and braces: never leave a gateway behind, even on failure paths
  if [ -n "${GW_PID:-}" ] && kill -0 "$GW_PID" 2>/dev/null; then
    kill "$GW_PID" 2>/dev/null || true
  fi
}
trap cleanup EXIT

JOB_CONFIG=config/distributed_smoke.toml

printf 'smoke:smoke-token\n' > "$OUT/tokens.txt"

"$BIN" serve --listen 127.0.0.1:0 --token_file "$OUT/tokens.txt" \
  --ledger "$OUT/usage.jsonl" >"$OUT/gateway.log" 2>&1 &
GW_PID=$!
ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's/.*listening on \([0-9.]*:[0-9]*\).*/\1/p' "$OUT/gateway.log" | head -n1)
  [ -n "$ADDR" ] && break
  if ! kill -0 "$GW_PID" 2>/dev/null; then
    echo "FAIL: gateway died during startup" >&2
    cat "$OUT/gateway.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [ -z "$ADDR" ]; then
  echo "FAIL: gateway never reported its address" >&2
  cat "$OUT/gateway.log" >&2
  exit 1
fi
echo "gateway at $ADDR (pid $GW_PID)"

echo "--- baseline: the same job via cola train"
"$BIN" train --config "$JOB_CONFIG" \
  --loss_out "$OUT/base_curves.json" --adapter_out "$OUT/base.adapter"

echo "--- liveness + auth"
"$BIN" http get "http://$ADDR/healthz" >"$OUT/healthz.json"
grep -q '"ok":true' "$OUT/healthz.json"
"$BIN" http get "http://$ADDR/v1/jobs/1" --token wrong-token --expect 401 \
  >/dev/null
echo "OK: /healthz is open, a wrong token answers 401"

echo "--- submit the job over HTTP"
SUBMIT_NS=$(date +%s%N)
"$BIN" http post "http://$ADDR/v1/fit" --token smoke-token \
  --body "$JOB_CONFIG" --expect 202 --out "$OUT/submit.json"
JOB=$(sed -n 's/.*"job":\([0-9][0-9]*\).*/\1/p' "$OUT/submit.json" | head -n1)
if [ -z "$JOB" ]; then
  echo "FAIL: no job id in the 202 body:" >&2
  cat "$OUT/submit.json" >&2
  exit 1
fi
echo "submitted as job $JOB"

echo "--- stream progress until the job completes"
"$BIN" http get "http://$ADDR/v1/jobs/$JOB/progress" --token smoke-token \
  --out "$OUT/progress.jsonl"
FIRST_NS=$(date +%s%N)
if ! grep -q '"done":true' "$OUT/progress.jsonl"; then
  echo "FAIL: progress stream never reached the terminal line:" >&2
  cat "$OUT/progress.jsonl" >&2
  exit 1
fi
LINES=$(wc -l < "$OUT/progress.jsonl")
echo "OK: streamed $LINES progress lines (submit->stream-drained $(( (FIRST_NS - SUBMIT_NS) / 1000000 )) ms)"

echo "--- fetched curves must byte-diff clean against cola train"
"$BIN" http get "http://$ADDR/v1/jobs/$JOB/curves" --token smoke-token \
  --out "$OUT/gw_curves.json"
if ! diff "$OUT/base_curves.json" "$OUT/gw_curves.json"; then
  echo "FAIL: gateway curves differ from the cola train baseline" >&2
  exit 1
fi
echo "OK: loss curves are byte-identical"

echo "--- fetched adapter bundle must be bit-exact too"
"$BIN" http get "http://$ADDR/v1/jobs/$JOB/adapter" --token smoke-token \
  --out "$OUT/gw.adapter"
if ! cmp "$OUT/base.adapter" "$OUT/gw.adapter"; then
  echo "FAIL: gateway adapter bundle differs from the cola train baseline" >&2
  exit 1
fi
echo "OK: adapter bundle is bit-exact ($(wc -c < "$OUT/gw.adapter") bytes)"

echo "--- the usage ledger attributed the run to the tenant"
# fire-and-forget: give the writer thread a beat to flush
for _ in $(seq 1 50); do
  grep -q '"tenant":"smoke"' "$OUT/usage.jsonl" 2>/dev/null && break
  sleep 0.1
done
if ! grep -q '"tenant":"smoke"' "$OUT/usage.jsonl"; then
  echo "FAIL: no smoke-tenant rows in the usage ledger" >&2
  cat "$OUT/usage.jsonl" >&2 || true
  exit 1
fi
ROWS=$(wc -l < "$OUT/usage.jsonl")
BYTES=$(wc -c < "$OUT/usage.jsonl")
echo "OK: ledger holds $ROWS rows ($BYTES bytes)"

echo "--- clean shutdown over the API"
"$BIN" http post "http://$ADDR/v1/shutdown" --token smoke-token --expect 200 \
  >/dev/null
if ! wait "$GW_PID"; then
  echo "FAIL: gateway exited non-zero after /v1/shutdown" >&2
  cat "$OUT/gateway.log" >&2
  exit 1
fi
GW_PID=""
echo "OK: gateway exited cleanly after POST /v1/shutdown"
